//! `padst lint` suite: every rule exercised on fixture trees (violation
//! detected; justified/annotated site passes), baseline suppression, JSON
//! round-trip and byte-determinism — plus the self-host checks: the real
//! repo tree is clean under all rules and its report matches the CI
//! golden byte for byte.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use padst::analysis::report::{Baseline, LintReport, Severity};
use padst::analysis::{run_lint, LintOptions};
use padst::util::json::Json;

/// A fixture repo under the OS temp dir: `rust/src/` + manifest +
/// baseline paths laid out exactly like the real tree.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(tag: &str) -> Fixture {
        let root =
            std::env::temp_dir().join(format!("padst_lint_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("rust/src")).unwrap();
        Fixture { root }
    }

    fn write(&self, rel: &str, contents: &str) -> &Fixture {
        let p = self.root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, contents).unwrap();
        self
    }

    fn opts(&self, rules: &[&str]) -> LintOptions {
        let mut o = LintOptions::new(self.root.clone());
        o.rules = rules.iter().map(|r| r.to_string()).collect::<BTreeSet<_>>();
        o
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

const MANIFEST: &str = r#"
[modules]
util = []
kernels_micro = []
kernels = ["kernels_micro", "util"]
perm = ["kernels_micro", "util"]
serve = ["kernels", "perm", "util"]
lib = []
main = ["*"]

[split]
"kernels::micro" = "kernels_micro"
"#;

fn lib_ok() -> &'static str {
    "#![forbid(unsafe_code)]\npub mod util;\n"
}

// ------------------------------------------------------------------- L1

#[test]
fn l1_flags_upward_edge_and_passes_allowed_ones() {
    let fx = Fixture::new("l1");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write("rust/src/util/mod.rs", "use crate::kernels::tune::Choice;\n")
        .write("rust/src/perm/mod.rs", "use crate::kernels::micro::Backend;\n")
        .write("rust/src/serve/mod.rs", "use crate::kernels::run_plan;\n");
    let out = run_lint(&fx.opts(&["L1"])).unwrap();
    // util -> kernels violates; perm -> kernels_micro (split) and
    // serve -> kernels are declared legal.
    assert_eq!(out.report.diagnostics.len(), 1, "{:?}", out.report.diagnostics);
    let d = &out.report.diagnostics[0];
    assert_eq!(d.rule, "L1");
    assert_eq!(d.file, "rust/src/util/mod.rs");
    assert_eq!(d.line, 1);
    assert!(d.msg.contains("util"), "{}", d.msg);
    assert!(d.msg.contains("kernels"), "{}", d.msg);
}

#[test]
fn l1_ignores_doc_comments_strings_and_test_regions() {
    let fx = Fixture::new("l1_skip");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/util/mod.rs",
        concat!(
            "//! See [`crate::kernels::tune`] for the tuner.\n",
            "pub fn path() -> &'static str { \"crate::kernels::tune\" }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use crate::kernels::micro::Backend;\n",
            "    fn t() { let _ = Backend::Scalar; }\n",
            "}\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L1"])).unwrap();
    assert!(out.report.diagnostics.is_empty(), "{:?}", out.report.diagnostics);
}

#[test]
fn l1_flags_undeclared_module() {
    let fx = Fixture::new("l1_undeclared");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write("rust/src/mystery.rs", "pub fn f() {}\n");
    let out = run_lint(&fx.opts(&["L1"])).unwrap();
    assert_eq!(out.report.diagnostics.len(), 1);
    assert!(out.report.diagnostics[0].msg.contains("mystery"));
}

#[test]
fn l1_without_manifest_is_an_error() {
    let fx = Fixture::new("l1_nomanifest");
    fx.write("rust/src/lib.rs", lib_ok());
    assert!(run_lint(&fx.opts(&["L1"])).is_err());
    // ...but rules that don't need the manifest still run.
    assert!(run_lint(&fx.opts(&["L6"])).is_ok());
}

// ------------------------------------------------------------------- L2

#[test]
fn l2_flags_allocation_in_annotated_fn_only() {
    let fx = Fixture::new("l2");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/util/mod.rs",
        concat!(
            "// lint: no-alloc\n",
            "pub fn hot(v: &mut Vec<u8>, s: &[u8]) {\n",
            "    v.push(1);\n",
            "    let _ = format!(\"x\");\n",
            "    let _: Vec<u8> = s.iter().copied().collect();\n",
            "    let _ = Box::new(3);\n",
            "}\n",
            "pub fn cold() -> Vec<u8> {\n",
            "    let mut v = Vec::new();\n",
            "    v.push(1);\n",
            "    v\n",
            "}\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L2"])).unwrap();
    // push, format!, collect, Box::new — all inside `hot`; `cold` is free
    // to allocate.
    assert_eq!(out.report.diagnostics.len(), 4, "{:?}", out.report.diagnostics);
    assert!(out.report.diagnostics.iter().all(|d| d.msg.contains("hot")));
}

#[test]
fn l2_clean_annotated_fn_and_inline_allow_pass() {
    let fx = Fixture::new("l2_ok");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/util/mod.rs",
        concat!(
            "// lint: no-alloc\n",
            "pub fn hot(y: &mut [f32], x: &[f32]) {\n",
            "    y.copy_from_slice(x);\n",
            "    // lint: allow(L2) startup-only scratch growth\n",
            "    let _ = Vec::<u8>::with_capacity(4);\n",
            "}\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L2"])).unwrap();
    assert!(out.report.diagnostics.is_empty(), "{:?}", out.report.diagnostics);
}

// ------------------------------------------------------------------- L3

#[test]
fn l3_requires_ordering_comment_on_strict_orderings() {
    let fx = Fixture::new("l3");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/util/mod.rs",
        concat!(
            "use std::sync::atomic::{AtomicUsize, Ordering};\n",
            "pub fn f(a: &AtomicUsize) -> usize {\n",
            "    a.store(1, Ordering::SeqCst);\n",
            "    // ordering: Acquire pairs with the publisher's Release.\n",
            "    let n = a.load(Ordering::Acquire);\n",
            "    n + a.load(Ordering::Relaxed)\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use super::*;\n",
            "    fn t(a: &AtomicUsize) { a.store(0, Ordering::SeqCst); }\n",
            "}\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L3"])).unwrap();
    // Only the bare SeqCst store gates: the Acquire is justified, Relaxed
    // is exempt, and the test-region SeqCst is skipped.
    assert_eq!(out.report.diagnostics.len(), 1, "{:?}", out.report.diagnostics);
    assert_eq!(out.report.diagnostics[0].line, 3);
    assert!(out.report.diagnostics[0].msg.contains("SeqCst"));
}

// ------------------------------------------------------------------- L4

#[test]
fn l4_flags_panics_in_annotated_fn() {
    let fx = Fixture::new("l4");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/serve/mod.rs",
        concat!(
            "// lint: no-panic\n",
            "pub fn frame_loop(x: Option<u32>) -> u32 {\n",
            "    if x.is_none() { panic!(\"boom\") }\n",
            "    x.unwrap()\n",
            "}\n",
            "pub fn helper(x: Option<u32>) -> u32 { x.unwrap() }\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L4"])).unwrap();
    assert_eq!(out.report.diagnostics.len(), 2, "{:?}", out.report.diagnostics);
    assert!(out.report.diagnostics.iter().all(|d| d.msg.contains("frame_loop")));
}

#[test]
fn l4_poison_idiom_passes() {
    let fx = Fixture::new("l4_ok");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/serve/mod.rs",
        concat!(
            "use std::sync::Mutex;\n",
            "// lint: no-panic\n",
            "pub fn frame_loop(m: &Mutex<u32>) -> u32 {\n",
            "    *m.lock().unwrap_or_else(|p| p.into_inner())\n",
            "}\n",
        ),
    );
    let out = run_lint(&fx.opts(&["L4"])).unwrap();
    assert!(out.report.diagnostics.is_empty(), "{:?}", out.report.diagnostics);
}

// ------------------------------------------------------------------- L5

#[test]
fn l5_flags_hardcoded_wire_version_and_duplicate_const() {
    let fx = Fixture::new("l5");
    fx.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok()).write(
        "rust/src/util/mod.rs",
        concat!(
            "pub const TUNE_SCHEMA_VERSION: u32 = 1;\n",
            "pub fn write(o: &mut Vec<(String, u32)>) {\n",
            "    o.push((\"tune_schema\".to_string(), 1));\n",
            "}\n",
        ),
    ).write(
        "rust/src/kernels/mod.rs",
        "pub const TUNE_SCHEMA_VERSION: u32 = 1;\n",
    );
    let out = run_lint(&fx.opts(&["L5"])).unwrap();
    let msgs: Vec<&str> = out.report.diagnostics.iter().map(|d| d.msg.as_str()).collect();
    assert_eq!(out.report.diagnostics.len(), 2, "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("hardcoded")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("more than once")), "{msgs:?}");
}

#[test]
fn l5_const_use_and_readme_agreement_pass() {
    let fx = Fixture::new("l5_ok");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write(
            "rust/src/util/mod.rs",
            concat!(
                "pub const TUNE_SCHEMA_VERSION: u32 = 3;\n",
                "pub fn write(o: &mut Vec<(String, u32)>) {\n",
                "    o.push((\"tune_schema\".to_string(), TUNE_SCHEMA_VERSION));\n",
                "}\n",
                "pub fn read(v: u32) -> bool { v == TUNE_SCHEMA_VERSION }\n",
            ),
        )
        .write("README.md", "| `tune_schema` | 3 | tuning table |\n");
    let out = run_lint(&fx.opts(&["L5"])).unwrap();
    assert!(out.report.diagnostics.is_empty(), "{:?}", out.report.diagnostics);
}

#[test]
fn l5_readme_disagreement_gates() {
    let fx = Fixture::new("l5_readme");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write("rust/src/util/mod.rs", "pub const TUNE_SCHEMA_VERSION: u32 = 2;\n")
        .write("README.md", "The table stamps `tune_schema`: 1 today.\n");
    let out = run_lint(&fx.opts(&["L5"])).unwrap();
    assert_eq!(out.report.diagnostics.len(), 1, "{:?}", out.report.diagnostics);
    assert_eq!(out.report.diagnostics[0].file, "README.md");
    assert!(out.report.diagnostics[0].msg.contains("tune_schema"));
}

// ------------------------------------------------------------------- L6

#[test]
fn l6_missing_forbid_unsafe_gates() {
    let fx = Fixture::new("l6");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", "pub mod util;\n");
    let out = run_lint(&fx.opts(&["L6"])).unwrap();
    assert_eq!(out.report.diagnostics.len(), 1);
    assert!(out.report.diagnostics[0].msg.contains("forbid(unsafe_code)"));

    let fx2 = Fixture::new("l6_ok");
    fx2.write("ci/lint/layers.toml", MANIFEST).write("rust/src/lib.rs", lib_ok());
    let out2 = run_lint(&fx2.opts(&["L6"])).unwrap();
    assert!(out2.report.diagnostics.is_empty());
}

// ------------------------------------------- baseline, report, determinism

#[test]
fn baseline_suppresses_accepted_findings() {
    let fx = Fixture::new("baseline");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write("rust/src/util/mod.rs", "use crate::kernels::tune::Choice;\n");
    // First run: one L1 finding, empty (missing) baseline.
    let out = run_lint(&fx.opts(&["L1"])).unwrap();
    assert_eq!(out.report.diagnostics.len(), 1);
    assert!(out.report.failed());
    // Accept it, exactly as --fix-baseline does.
    fx.write("ci/lint/baseline.json", &Baseline::render(&out.all));
    let out2 = run_lint(&fx.opts(&["L1"])).unwrap();
    assert!(out2.report.diagnostics.is_empty());
    assert_eq!(out2.report.suppressed, 1);
    assert!(!out2.report.failed());
    // `all` still carries the finding for the next --fix-baseline.
    assert_eq!(out2.all.len(), 1);
}

#[test]
fn report_json_round_trips_and_is_byte_deterministic() {
    let fx = Fixture::new("determinism");
    fx.write("ci/lint/layers.toml", MANIFEST)
        .write("rust/src/lib.rs", lib_ok())
        .write("rust/src/util/mod.rs", "use crate::kernels::tune::Choice;\n")
        .write("rust/src/perm/mod.rs", "x.store(1, Ordering::SeqCst);\n");
    let run = || {
        let out = run_lint(&fx.opts(&["L1", "L3", "L6"])).unwrap();
        out.report.to_json().to_string_pretty()
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "two runs over the same tree must serialise identically");
    let re = LintReport::parse(&Json::parse(&a).unwrap()).unwrap();
    assert_eq!(re.diagnostics.len(), 2);
    assert!(re.diagnostics.iter().all(|d| d.severity == Severity::Error));
    // Canonical order: sorted by (file, line, rule, msg).
    let files: Vec<&str> = re.diagnostics.iter().map(|d| d.file.as_str()).collect();
    assert_eq!(files, vec!["rust/src/perm/mod.rs", "rust/src/util/mod.rs"]);
}

// ------------------------------------------------------- self-host checks

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR = <repo>/rust
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

/// The real tree is clean under every rule with the committed (empty)
/// baseline — satellite guarantee of the lint PR, enforced forever after.
#[test]
fn repo_tree_is_clean() {
    let opts = LintOptions::new(repo_root());
    let out = run_lint(&opts).unwrap();
    let rendered: Vec<String> =
        out.report.diagnostics.iter().map(|d| d.render()).collect();
    assert!(rendered.is_empty(), "repo lint findings:\n{}", rendered.join("\n"));
    assert_eq!(out.report.suppressed, 0, "committed baseline must stay empty");
}

/// The repo report matches the CI golden byte for byte (the same file the
/// blocking `lint` CI job diffs).
#[test]
fn repo_report_matches_ci_golden() {
    let root = repo_root();
    let out = run_lint(&LintOptions::new(root.clone())).unwrap();
    let mut text = out.report.to_json().to_string_pretty();
    text.push('\n');
    let golden = std::fs::read_to_string(root.join("ci/golden/lint_smoke.out"))
        .expect("ci/golden/lint_smoke.out");
    assert_eq!(text, golden);
}

/// The committed baseline file parses and is empty.
#[test]
fn committed_baseline_is_empty() {
    let b = Baseline::load(&repo_root().join("ci/lint/baseline.json")).unwrap();
    assert!(b.is_empty());
}
