//! Property and integration tests of the typed permutation subsystem:
//! planted-permutation recovery through the spec'd decode path, index-map
//! algebra consistency with the compression layer, spec round-trips
//! (including bare-name back-compat), and checkpoint save/load of the
//! typed state machine across resume.

use std::collections::HashMap;

use padst::coordinator::{checkpoint, TrainState};
use padst::perm::{self, model::{resolve_perm, sites_from_vals, PermState}, SinkhornScratch};
use padst::sparsity::compress::{compress_rows, decompress_rows};
use padst::sparsity::patterns::make_diag_mask;
use padst::tensor::Tensor;
use padst::util::Rng;

/// `decode(soft_perm(..))` recovers a planted permutation under small
/// logit noise, through the model's own decode path (Sinkhorn scratch +
/// Hungarian), across seeds and spec'd iteration counts.
#[test]
fn prop_decode_recovers_planted_permutation() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(100 + seed);
        let n = 12 + (seed as usize % 3) * 4;
        let planted = rng.permutation(n);
        let mut logits = vec![0.0f32; n * n];
        for v in logits.iter_mut() {
            *v = 0.3 * rng.normal();
        }
        for (i, &j) in planted.iter().enumerate() {
            logits[i * n + j] += 4.0;
        }
        let mut scratch = SinkhornScratch::new();
        for spec in ["learned", "learned:sinkhorn=24", "learned:tau=0.5"] {
            let model = resolve_perm(spec).unwrap();
            let idx = model.decode_logits(&logits, n, &mut scratch).unwrap();
            assert_eq!(idx, planted, "seed {seed} spec {spec}: decode missed the plant");
        }
    }
}

/// Index-map composition is associative, and folding a permutation into
/// the row-compressed index stream is inverse-consistent with
/// `decompress_rows`: decompressing through `invert(p)` recovers exactly
/// the masked dense weights.
#[test]
fn prop_index_algebra_consistent_with_compression() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(200 + seed);
        let n = 16;
        let a = rng.permutation(n);
        let b = rng.permutation(n);
        let c = rng.permutation(n);
        // Associativity.
        assert_eq!(
            perm::compose(&perm::compose(&a, &b), &c),
            perm::compose(&a, &perm::compose(&b, &c)),
            "seed {seed}"
        );
        // Inverse consistency: inv ∘ a = identity on indices.
        let inv = perm::invert(&a);
        assert_eq!(perm::compose(&inv, &a), (0..n).collect::<Vec<_>>(), "seed {seed}");

        // Through the compression layer: the stored index stream is
        // p[j], and decompressing through invert(p) must give back the
        // masked dense weights bit-for-bit.
        let (rows, cols) = (12usize, n);
        let mask = make_diag_mask(rows, cols, 3, &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let p_i32: Vec<i32> = a.iter().map(|&x| x as i32).collect();
        let inv_i32: Vec<i32> = inv.iter().map(|&x| x as i32).collect();
        let rc = compress_rows(&w, &mask, 3, Some(&p_i32));
        let back = decompress_rows(&rc, Some(&inv_i32));
        for i in 0..rows {
            for j in 0..cols {
                let want = if mask.get(i, j) { w[i * cols + j] } else { 0.0 };
                assert_eq!(back[i * cols + j], want, "seed {seed} ({i},{j})");
            }
        }
    }
}

/// Spec round-trip including bare-name back-compat: every canonical spec
/// re-parses to itself, bare names canonicalise from their explicit
/// default forms, and every historical mode string resolves.
#[test]
fn spec_roundtrip_and_bare_name_back_compat() {
    // Historical strings (CLI flags, manifests, journals) all resolve and
    // print back as themselves.
    for legacy in ["none", "random", "learned", "kaleidoscope"] {
        let m = resolve_perm(legacy).unwrap();
        assert_eq!(m.spec(), legacy);
        assert_eq!(resolve_perm(&m.spec()).unwrap().spec(), legacy);
    }
    // Parameterised forms round-trip canonically...
    for spec in [
        "learned:sinkhorn=24:tau=0.5",
        "learned:patience=5:threshold=0.1",
        "random:seed=7",
        "kaleidoscope:threshold=0.05",
    ] {
        assert_eq!(resolve_perm(spec).unwrap().spec(), spec);
    }
    // ... and explicit defaults canonicalise to the bare name.
    assert_eq!(resolve_perm("learned:sinkhorn=12:tau=1").unwrap().spec(), "learned");
    assert_eq!(resolve_perm("random:seed=1000").unwrap().spec(), "random");
}

/// Checkpoint save/load preserves `Hard` state and hardened flags across
/// resume: a run whose sites partially hardened reloads with the same
/// index maps, flags, and typed classification.
#[test]
fn checkpoint_preserves_hard_state_across_resume() {
    let model = resolve_perm("learned").unwrap();
    let names: Vec<String> = vec!["l0.fc1".into(), "l0.attn".into(), "l1.fc1".into()];
    let n = 8usize;
    let mut rng = Rng::new(42);

    let mut vals = HashMap::new();
    let mut flags = Vec::new();
    let hard_map: Vec<usize> = (0..n).rev().collect();
    for (si, name) in names.iter().enumerate() {
        let mut site = model.init_site(si, name, n, &mut rng);
        if si == 1 {
            site.harden(hard_map.clone());
        }
        flags.push(site.hard_flag());
        site.export_into(&mut vals);
        // Checkpoints key site order off the mask tensors.
        vals.insert(
            format!("mask.{name}"),
            Tensor::from_f32(&[2, n], vec![1.0; 2 * n]),
        );
    }
    vals.insert("hard_flags".into(), Tensor::from_f32(&[names.len()], flags.clone()));
    vals.insert("step".into(), Tensor::scalar(17.0));
    let state = TrainState { vals, site_names: names.clone(), budgets: vec![2 * n; 3] };

    let dir = std::env::temp_dir().join("padst_perm_model_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.tnz");
    checkpoint::save(&path, &state).unwrap();
    let back = checkpoint::load(&path).unwrap();

    // Raw tensors survived.
    assert_eq!(back.site_names, names);
    assert_eq!(back.vals["hard_flags"].f32s(), &flags[..]);
    let idx: Vec<usize> =
        back.vals["perm_idx.l0.attn"].i32s().iter().map(|&x| x as usize).collect();
    assert_eq!(idx, hard_map);

    // The typed reconstruction classifies every site as before the save.
    let widths = vec![n; names.len()];
    let sites = sites_from_vals(model.as_ref(), &names, &widths, &back.vals).unwrap();
    assert!(matches!(sites[0].state, PermState::Soft { .. }));
    assert_eq!(sites[1].state.index_map(), Some(&hard_map[..]));
    assert!(matches!(sites[2].state, PermState::Soft { .. }));
    // Soft logits rebind bit-identically.
    assert_eq!(
        sites[0].logits().unwrap().f32s(),
        back.vals["perm_logits.l0.fc1"].f32s()
    );
    // Hard flags re-derive from the states.
    assert_eq!(
        sites.iter().map(|s| s.hard_flag()).collect::<Vec<_>>(),
        flags
    );
}

/// The identity-distance metric is invariant across the soft decode and
/// the stored hard map once a site hardens: hardening writes exactly the
/// map the final analysis would decode.
#[test]
fn harden_decode_matches_final_decode() {
    let model = resolve_perm("learned").unwrap();
    let n = 10;
    let mut rng = Rng::new(7);
    let planted = rng.permutation(n);
    let mut logits = vec![0.0f32; n * n];
    for v in logits.iter_mut() {
        *v = 0.2 * rng.normal();
    }
    for (i, &j) in planted.iter().enumerate() {
        logits[i * n + j] += 5.0;
    }
    let mut s1 = SinkhornScratch::new();
    let mut s2 = SinkhornScratch::new();
    let at_harden = model.decode_logits(&logits, n, &mut s1).unwrap();
    let at_finish = model.decode_logits(&logits, n, &mut s2).unwrap();
    assert_eq!(at_harden, at_finish);
    assert_eq!(
        perm::identity_distance(&at_harden),
        perm::identity_distance(&planted)
    );
}
