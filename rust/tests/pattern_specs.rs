//! End-to-end checks for the `SparsePattern` trait API and the spec
//! registry — the non-artifact half of the pattern-layer contract:
//!
//! * spec strings thread through the sweep grid (method synthesis, cell
//!   fingerprints) exactly as the CLI would drive them;
//! * every family's kernel plan reproduces the masked-dense oracle on
//!   every compiled backend — i.e. `compress` really feeds the right
//!   `Backend`-dispatched driver, including the non-default `block:4` and
//!   `nm:1:4` specs CI exercises on every PR;
//! * telemetry records carry the spec string through a JSON round-trip.

use padst::coordinator::sweep::{method_by_name, method_fingerprint, plan_grid};
use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::micro::Backend;
use padst::kernels::run_plan;
use padst::sparsity::pattern::resolve_pattern;
use padst::sparsity::patterns::Mask;
use padst::util::Rng;

/// Reference masked-dense matmul.
fn oracle(x: &[f32], w: &[f32], mask: &Mask, batch: usize) -> Vec<f32> {
    let (rows, cols) = (mask.rows, mask.cols);
    let mut y = vec![0.0f32; batch * rows];
    for b in 0..batch {
        for i in 0..rows {
            let mut acc = 0.0;
            for j in 0..cols {
                if mask.get(i, j) {
                    acc += w[i * cols + j] * x[b * cols + j];
                }
            }
            y[b * rows + i] = acc;
        }
    }
    y
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Every family's plan — default and parameterised specs — must match the
/// masked-dense oracle on every backend.  This is the compile-and-run
/// check that `block:4` / `nm:1:4` execute end to end on every PR.
#[test]
fn kernel_plans_match_oracle_for_every_spec() {
    let (batch, rows, cols) = (4usize, 32usize, 64usize);
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();

    for spec in [
        "diag", "diag:4", "banded", "banded:3", "block", "block:4", "block:8", "nm", "nm:1:4",
        "nm:2:8", "nm::8", "butterfly", "unstructured", "dense",
    ] {
        let pattern = resolve_pattern(spec).unwrap();
        let mask = pattern.init_mask(rows, cols, 0.25, &mut rng).unwrap();
        assert!(pattern.validate(&mask).is_ok(), "{spec}: init mask not in-family");
        let want = oracle(&x, &w, &mask, batch);
        let plan = pattern.compress(&w, &mask, None);
        for &backend in Backend::all() {
            let mut y = vec![f32::NAN; batch * rows];
            run_plan(&plan, &x, batch, &mut y, backend);
            assert!(
                max_diff(&y, &want) < 1e-3,
                "{spec} [{}]: plan output differs from oracle",
                backend.name()
            );
        }
    }
}

/// Folding a permutation into the plan's index stream equals the explicit
/// shuffle-then-multiply path, for every family (the Eqn. 16/18 trick the
/// pattern objects now own).
#[test]
fn reindex_plans_equal_shuffle_for_every_spec() {
    let (batch, rows, cols) = (3usize, 32usize, 64usize);
    let mut rng = Rng::new(12);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let perm: Vec<i32> = rng.permutation(cols).iter().map(|&p| p as i32).collect();
    // Shuffled input: xp[b, i] = x[b, perm[i]].
    let mut xp = vec![0.0f32; batch * cols];
    for b in 0..batch {
        for i in 0..cols {
            xp[b * cols + i] = x[b * cols + perm[i] as usize];
        }
    }

    for spec in ["diag", "diag:4", "block", "block:4", "nm:1:4", "butterfly", "unstructured"] {
        let pattern = resolve_pattern(spec).unwrap();
        let mask = pattern.init_mask(rows, cols, 0.25, &mut rng).unwrap();
        let backend = Backend::default_backend();

        let mut ya = vec![0.0f32; batch * rows];
        run_plan(&pattern.compress(&w, &mask, None), &xp, batch, &mut ya, backend);
        let mut yb = vec![0.0f32; batch * rows];
        run_plan(&pattern.compress(&w, &mask, Some(&perm)), &x, batch, &mut yb, backend);
        assert!(
            max_diff(&ya, &yb) < 1e-4,
            "{spec}: reindexed plan differs from explicit shuffle"
        );
    }
}

/// Specs thread into the sweep grid: spec-synthesized methods expand into
/// cells whose fingerprints carry the spec, next to zoo methods.
#[test]
fn specs_thread_into_sweep_grid_fingerprints() {
    let methods = ["RigL", "block:4", "nm:1:4"]
        .iter()
        .map(|n| method_by_name(n).unwrap())
        .collect::<Vec<_>>();
    let cells = plan_grid(&methods, &[0.8]);
    assert_eq!(cells.len(), 3);
    let fps: Vec<String> = cells.iter().map(|(m, _)| method_fingerprint(m)).collect();
    assert_eq!(
        fps,
        [
            "unstructured|none|RigL".to_string(),
            "block:4|none|RigL".to_string(),
            "nm:1:4|none|RigL".to_string(),
        ]
    );
}

/// Telemetry: the pattern spec survives a BenchReport JSON round-trip and
/// stays out of the record identity.
#[test]
fn bench_records_carry_pattern_specs() {
    let mut report = BenchReport::new("pattern_specs_test", 1);
    report.push(
        BenchRecord::value("inference", "vit_b16/fc1 block:8 s0.9 none")
            .with_pattern("block:8")
            .with_metric("speedup_vs_dense", 2.5),
    );
    report.push(BenchRecord::value("memory", "vit_tiny/baseline"));
    let back = BenchReport::parse(&report.to_json().to_string_pretty()).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.records[0].pattern, "block:8");
    assert_eq!(back.records[1].pattern, "", "absent pattern reads back empty");
    assert_eq!(
        back.records[0].id(),
        "inference/vit_b16/fc1 block:8 s0.9 none",
        "pattern is provenance, not identity"
    );
}
