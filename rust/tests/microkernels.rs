//! Microkernel-layer property tests (`padst::kernels::micro`):
//!
//! * every dot shape matches a strict-order naive reference across widths
//!   1..=33 — every tail length relative to the 8-lane tile — for every
//!   backend compiled into this binary;
//! * the multi-row shapes (`dot_rows4`, `dot_gather4`) reproduce the
//!   single-row shapes bit-for-bit per row (what lets `_mt` shards split
//!   register blocks anywhere without changing an output bit);
//! * the full drivers match the masked-dense oracle on every backend at
//!   panel widths 1..=33;
//! * the backends agree with each other within 1e-4;
//! * NaN and infinity propagate through the tiled reduction — including
//!   when the poisoned element sits in the tail — instead of being masked
//!   by lane padding.

use padst::kernels::micro::{self, Backend};
use padst::kernels::{
    block_matmul_with, csr_from_mask, csr_matmul_with, dense_matmul_blocked_with,
    gather_matmul_with,
};
use padst::sparsity::compress::{compress_blocks, compress_rows};
use padst::sparsity::patterns::{make_block_mask, make_diag_mask, make_unstructured_mask, Mask};
use padst::util::Rng;

/// Strict-order reference dot in f64 (tight enough at these widths that a
/// 1e-4 band holds for any summation order).
fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum::<f64>() as f32
}

fn naive_gather(vals: &[f32], idx: &[i32], x: &[f32]) -> f32 {
    vals.iter()
        .zip(idx)
        .map(|(&v, &j)| v as f64 * x[j as usize] as f64)
        .sum::<f64>() as f32
}

/// Masked-dense oracle for the full drivers.
fn oracle(x: &[f32], w: &[f32], mask: &Mask, batch: usize) -> Vec<f32> {
    let (rows, cols) = (mask.rows, mask.cols);
    let mut y = vec![0.0f32; batch * rows];
    for b in 0..batch {
        for i in 0..rows {
            let mut acc = 0.0f64;
            for j in 0..cols {
                if mask.get(i, j) {
                    acc += w[i * cols + j] as f64 * x[b * cols + j] as f64;
                }
            }
            y[b * rows + i] = acc as f32;
        }
    }
    y
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

// ------------------------------------------------------- dot shapes 1..=33

#[test]
fn dot_matches_naive_for_every_width_and_backend() {
    let mut rng = Rng::new(0xD07);
    for width in 1..=33usize {
        let a: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
        let want = naive_dot(&a, &b);
        for &backend in Backend::all() {
            let got = micro::dot(&a, &b, backend);
            assert!(
                (got - want).abs() < 1e-4,
                "dot width {width} [{}]: {got} vs {want}",
                backend.name()
            );
        }
    }
}

#[test]
fn dot_gather_matches_naive_for_every_width_and_backend() {
    let mut rng = Rng::new(0x6A0);
    let n = 64;
    for width in 1..=33usize {
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let vals: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
        let idx: Vec<i32> = (0..width).map(|_| rng.below(n) as i32).collect();
        let want = naive_gather(&vals, &idx, &x);
        for &backend in Backend::all() {
            let got = micro::dot_gather(&vals, &idx, &x, backend);
            assert!(
                (got - want).abs() < 1e-4,
                "dot_gather width {width} [{}]: {got} vs {want}",
                backend.name()
            );
        }
    }
}

/// The `_mt` bit-identity contract rests on this: row i of a multi-row
/// microkernel call must equal the single-row call to the bit, at every
/// tail length.
#[test]
fn multi_row_shapes_reproduce_single_row_bitwise() {
    let mut rng = Rng::new(0x404);
    let n = 64;
    for width in 1..=33usize {
        let ws: Vec<Vec<f32>> =
            (0..4).map(|_| (0..width).map(|_| rng.normal()).collect()).collect();
        let x: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
        let xs: Vec<Vec<f32>> = (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let vals: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
        let idx: Vec<i32> = (0..width).map(|_| rng.below(n) as i32).collect();
        for &backend in Backend::all() {
            let rows = micro::dot_rows4(&ws[0], &ws[1], &ws[2], &ws[3], &x, backend);
            for (r, w) in ws.iter().enumerate() {
                let single = micro::dot(w, &x, backend);
                assert_eq!(
                    rows[r].to_bits(),
                    single.to_bits(),
                    "dot_rows4 row {r} width {width} [{}]",
                    backend.name()
                );
            }
            let g4 = micro::dot_gather4(&vals, &idx, &xs[0], &xs[1], &xs[2], &xs[3], backend);
            for (r, xr) in xs.iter().enumerate() {
                let single = micro::dot_gather(&vals, &idx, xr, backend);
                assert_eq!(
                    g4[r].to_bits(),
                    single.to_bits(),
                    "dot_gather4 row {r} width {width} [{}]",
                    backend.name()
                );
            }
        }
    }
}

// ------------------------------------------- full drivers vs oracle 1..=33

/// Gather driver at every panel width 1..=33 (diag-K masks with K = the
/// width): all tail lengths of the row microkernel, against the
/// masked-dense oracle, for every backend.
#[test]
fn gather_driver_matches_oracle_at_every_panel_width() {
    let mut meta = Rng::new(0x9A7);
    let (batch, rows, cols) = (3usize, 16usize, 40usize);
    for k in 1..=33usize {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let mask = make_diag_mask(rows, cols, k.min(cols), &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let kk = (0..rows).map(|i| mask.row_nnz(i)).max().unwrap();
        let rc = compress_rows(&w, &mask, kk, None);
        let want = oracle(&x, &w, &mask, batch);
        for &backend in Backend::all() {
            let mut y = vec![0.0f32; batch * rows];
            gather_matmul_with(&x, &rc, batch, &mut y, backend);
            let d = max_diff(&y, &want);
            assert!(d < 1e-4, "k={k} seed {seed} [{}]: {d}", backend.name());
        }
    }
}

#[test]
fn all_backends_agree_on_every_kernel() {
    let mut rng = Rng::new(0xE0);
    let (batch, rows, cols) = (5usize, 64usize, 96usize);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();

    let dm = make_diag_mask(rows, cols, 11, &mut rng);
    let rc = compress_rows(&w, &dm, 11, None);
    let um = make_unstructured_mask(rows, cols, 0.2, &mut rng);
    let csr = csr_from_mask(&w, &um);
    let bm = make_block_mask(rows, cols, 0.25, 16, &mut rng);
    let bc = compress_blocks(&w, &bm, 16);

    let run = |backend: Backend| -> [Vec<f32>; 4] {
        let mut yg = vec![0.0f32; batch * rows];
        gather_matmul_with(&x, &rc, batch, &mut yg, backend);
        let mut yc = vec![0.0f32; batch * rows];
        csr_matmul_with(&x, &csr, batch, &mut yc, backend);
        let mut yb = vec![0.0f32; batch * rows];
        block_matmul_with(&x, &bc, batch, &mut yb, backend);
        let mut yd = vec![0.0f32; batch * rows];
        dense_matmul_blocked_with(&x, &w, batch, rows, cols, &mut yd, backend);
        [yg, yc, yb, yd]
    };

    let reference = run(Backend::Scalar);
    for &backend in Backend::all() {
        let got = run(backend);
        for (which, (a, b)) in reference.iter().zip(&got).enumerate() {
            let d = max_diff(a, b);
            assert!(
                d < 1e-4,
                "kernel {which} scalar vs {}: max diff {d}",
                backend.name()
            );
        }
    }
}

// ------------------------------------------------------ non-finite inputs

/// NaN in the weights must surface in the output — in the 8-lane body and
/// in the tail — for every backend.  Lane padding or reordering must never
/// mask a poisoned element.
#[test]
fn nan_propagates_through_every_backend() {
    let mut rng = Rng::new(0xAA);
    for width in [1usize, 7, 8, 9, 16, 19, 33] {
        for poison_slot in [0, width / 2, width - 1] {
            let mut a: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..width).map(|_| rng.normal()).collect();
            a[poison_slot] = f32::NAN;
            for &backend in Backend::all() {
                let d = micro::dot(&a, &b, backend);
                assert!(
                    d.is_nan(),
                    "dot width {width} poison {poison_slot} [{}]: {d}",
                    backend.name()
                );
                let idx: Vec<i32> = (0..width as i32).collect();
                let g = micro::dot_gather(&a, &idx, &b, backend);
                assert!(
                    g.is_nan(),
                    "dot_gather width {width} poison {poison_slot} [{}]: {g}",
                    backend.name()
                );
            }
        }
    }
}

/// NaN in the *activations* at a gathered index propagates too (the index
/// stream must not skip it), and infinities survive the tiled reduction.
#[test]
fn nan_in_x_and_infinities_propagate() {
    let mut rng = Rng::new(0xAB);
    let n = 32;
    let width = 13; // 8-lane body + 5-tail
    let vals: Vec<f32> = (0..width).map(|_| rng.normal().abs() + 0.125).collect();
    let idx: Vec<i32> = (0..width as i32).collect();
    let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    x[10] = f32::NAN; // gathered by idx slot 10

    for &backend in Backend::all() {
        let g = micro::dot_gather(&vals, &idx, &x, backend);
        assert!(g.is_nan(), "NaN x [{}]: {g}", backend.name());
    }
    x[10] = f32::INFINITY;
    for &backend in Backend::all() {
        let g = micro::dot_gather(&vals, &idx, &x, backend);
        assert!(
            g.is_infinite() && g > 0.0,
            "inf x (positive vals) [{}]: {g}",
            backend.name()
        );
    }
    // Inf in the tail slot (index 12 >= 8) as well.
    x[10] = 1.0;
    x[12] = f32::NEG_INFINITY;
    for &backend in Backend::all() {
        let g = micro::dot_gather(&vals, &idx, &x, backend);
        assert!(
            g.is_infinite() && g < 0.0,
            "-inf tail [{}]: {g}",
            backend.name()
        );
    }
}

/// NaN weights poison the full block driver output (the tiled reduction
/// inside `block_row_matmul` must not drop it).
#[test]
fn nan_propagates_through_block_driver() {
    let mut rng = Rng::new(0xAC);
    let (batch, rows, cols) = (2usize, 32usize, 32usize);
    let mask = make_block_mask(rows, cols, 0.5, 16, &mut rng);
    let mut w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    // Poison one weight inside an active block.
    let (pi, pj) = (0..rows)
        .flat_map(|i| (0..cols).map(move |j| (i, j)))
        .find(|&(i, j)| mask.get(i, j))
        .expect("mask has an active block");
    w[pi * cols + pj] = f32::NAN;
    let bc = compress_blocks(&w, &mask, 16);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    for &backend in Backend::all() {
        let mut y = vec![0.0f32; batch * rows];
        block_matmul_with(&x, &bc, batch, &mut y, backend);
        assert!(
            y[pi].is_nan(),
            "block output row {pi} should be NaN [{}]",
            backend.name()
        );
    }
}
