//! Property tests for the scoped-thread kernel execution layer
//! (`padst::kernels::parallel`): for every structure family, every
//! microkernel backend compiled into this binary, and random geometry,
//! the parallel kernels must reproduce the serial kernels **bit-for-bit**
//! (`f32::to_bits` equality, not epsilon closeness) at 1, 2, and 8
//! threads.  This is the determinism contract that lets the Fig. 3
//! benches and the coordinator switch thread counts without changing a
//! single reproduced number — per backend; *across* backends the
//! summation order legitimately differs (tests/microkernels.rs covers
//! that equivalence at tolerance).
//!
//! Hand-rolled generator pattern (no proptest in the offline build): every
//! case prints its seed on failure for reproduction, mirroring
//! tests/prop_invariants.rs.

use padst::kernels::micro::Backend;
use padst::kernels::{
    block_matmul_mt_with, block_matmul_with, csr_from_mask, csr_matmul_mt_with, csr_matmul_with,
    dense_matmul_blocked_mt_with, dense_matmul_blocked_with, gather_matmul_mt_with,
    gather_matmul_with,
};
use padst::sparsity::compress::{compress_blocks, compress_rows};
use padst::sparsity::pattern::resolve_pattern;
use padst::util::Rng;

const CASES: usize = 30;
const THREADS: [usize; 3] = [1, 2, 8];

/// Dims divisible by the block size 16, so every family (incl. block and
/// N:M group-16) is valid at every drawn geometry.
fn arb_dims(rng: &mut Rng) -> (usize, usize, usize) {
    let batch = [1usize, 2, 3, 5, 8, 64][rng.below(6)];
    let rows = [16usize, 32, 48, 64, 96][rng.below(5)];
    let cols = [16usize, 32, 64, 96, 128][rng.below(5)];
    (batch, rows, cols)
}

fn assert_bits_eq(serial: &[f32], parallel: &[f32], what: &str) {
    assert_eq!(serial.len(), parallel.len(), "{what}: length mismatch");
    for (p, (a, b)) in serial.iter().zip(parallel).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: element {p} differs ({a} vs {b})"
        );
    }
}

#[test]
fn prop_gather_matmul_mt_bit_identical_per_backend() {
    let mut meta = Rng::new(0x6A7);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let (batch, rows, cols) = arb_dims(&mut rng);
        let density = [0.05, 0.1, 0.25][rng.below(3)];
        // Diag exercises the row-gather form; N:M and butterfly share it.
        let spec = ["diag", "nm", "butterfly"][rng.below(3)];
        let mask = resolve_pattern(spec)
            .unwrap()
            .init_mask(rows, cols, density, &mut rng)
            .unwrap();
        let k = (0..rows).map(|i| mask.row_nnz(i)).max().unwrap();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let rc = compress_rows(&w, &mask, k, None);

        for &backend in Backend::all() {
            let mut ys = vec![0.0f32; batch * rows];
            gather_matmul_with(&x, &rc, batch, &mut ys, backend);
            for threads in THREADS {
                // NaN poison: every element must be written.
                let mut ym = vec![f32::NAN; batch * rows];
                gather_matmul_mt_with(&x, &rc, batch, &mut ym, threads, backend);
                assert_bits_eq(
                    &ys,
                    &ym,
                    &format!(
                        "case {case} seed {seed} {spec} [{}] t={threads}",
                        backend.name()
                    ),
                );
            }
        }
    }
}

#[test]
fn prop_csr_matmul_mt_bit_identical_per_backend() {
    let mut meta = Rng::new(0xC58);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let (batch, rows, cols) = arb_dims(&mut rng);
        let density = [0.05, 0.1, 0.25][rng.below(3)];
        let mask = resolve_pattern("unstructured")
            .unwrap()
            .init_mask(rows, cols, density, &mut rng)
            .unwrap();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let csr = csr_from_mask(&w, &mask);

        for &backend in Backend::all() {
            let mut ys = vec![0.0f32; batch * rows];
            csr_matmul_with(&x, &csr, batch, &mut ys, backend);
            for threads in THREADS {
                let mut ym = vec![f32::NAN; batch * rows];
                csr_matmul_mt_with(&x, &csr, batch, &mut ym, threads, backend);
                assert_bits_eq(
                    &ys,
                    &ym,
                    &format!(
                        "case {case} seed {seed} csr [{}] t={threads}",
                        backend.name()
                    ),
                );
            }
        }
    }
}

#[test]
fn prop_block_matmul_mt_bit_identical_per_backend() {
    let mut meta = Rng::new(0xB70);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let (batch, rows, cols) = arb_dims(&mut rng);
        let density = [0.1, 0.25, 0.5][rng.below(3)];
        let mask = resolve_pattern("block")
            .unwrap()
            .init_mask(rows, cols, density, &mut rng)
            .unwrap();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let bc = compress_blocks(&w, &mask, 16);

        for &backend in Backend::all() {
            let mut ys = vec![0.0f32; batch * rows];
            block_matmul_with(&x, &bc, batch, &mut ys, backend);
            for threads in THREADS {
                let mut ym = vec![f32::NAN; batch * rows];
                block_matmul_mt_with(&x, &bc, batch, &mut ym, threads, backend);
                assert_bits_eq(
                    &ys,
                    &ym,
                    &format!(
                        "case {case} seed {seed} block [{}] t={threads}",
                        backend.name()
                    ),
                );
            }
        }
    }
}

#[test]
fn prop_dense_matmul_blocked_mt_bit_identical_per_backend() {
    let mut meta = Rng::new(0xDE5E);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        // Dense has no block-size constraint: also draw odd row counts to
        // exercise register-block tails at chunk boundaries (a chunk split
        // may land mid-4-row-block; the microkernel row contract makes
        // that safe).
        let batch = [1usize, 2, 5, 64][rng.below(4)];
        let rows = [7usize, 16, 33, 64, 97][rng.below(5)];
        let cols = [13usize, 32, 65, 96][rng.below(4)];
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();

        for &backend in Backend::all() {
            let mut ys = vec![0.0f32; batch * rows];
            dense_matmul_blocked_with(&x, &w, batch, rows, cols, &mut ys, backend);
            for threads in THREADS {
                let mut ym = vec![f32::NAN; batch * rows];
                dense_matmul_blocked_mt_with(&x, &w, batch, rows, cols, &mut ym, threads, backend);
                assert_bits_eq(
                    &ys,
                    &ym,
                    &format!(
                        "case {case} seed {seed} dense [{}] t={threads}",
                        backend.name()
                    ),
                );
            }
        }
    }
}

/// Thread counts far beyond the unit count must degrade gracefully (clamp,
/// not panic or leave gaps), including the batch=1, rows=1-block edge.
#[test]
fn oversubscribed_threads_are_clamped() {
    let mut rng = Rng::new(0x05);
    let (batch, rows, cols) = (1usize, 16usize, 32usize);
    let mask = resolve_pattern("block")
        .unwrap()
        .init_mask(rows, cols, 0.5, &mut rng)
        .unwrap();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let bc = compress_blocks(&w, &mask, 16);
    for &backend in Backend::all() {
        let mut ys = vec![0.0f32; batch * rows];
        let mut ym = vec![f32::NAN; batch * rows];
        block_matmul_with(&x, &bc, batch, &mut ys, backend);
        block_matmul_mt_with(&x, &bc, batch, &mut ym, 1000, backend);
        for (a, b) in ys.iter().zip(&ym) {
            assert_eq!(a.to_bits(), b.to_bits(), "[{}]", backend.name());
        }
    }
}
