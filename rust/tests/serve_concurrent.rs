//! ISSUE 10 cross-connection surface for `padst serve`: two connections
//! over one shared plan cache answer bit-identically to the same
//! requests served sequentially (per backend x threads, either wire
//! format), `NodeObs` registration de-duplicates across connections
//! (the satellite bugfix), the warm-path zero-alloc fingerprint holds
//! per connection, hot reloads propagate to live connections, and
//! `CheckpointWatch` turns an mtime change into a generation bump.
//! Single-connection protocol behaviour lives in `serve_protocol.rs`.

use std::collections::HashMap;
use std::sync::Barrier;

use padst::coordinator::{checkpoint, TrainState};
use padst::kernels::micro::Backend;
use padst::perm::model::resolve_perm;
use padst::serve::{serve, CheckpointWatch, NodeOpts, Request, Response, SessionCtx};
use padst::sparsity::pattern::resolve_pattern;
use padst::tensor::Tensor;
use padst::util::Rng;

const ROWS: usize = 32;
const COLS: usize = 64;

fn state_for(spec: &str, seed: u64, with_perm: bool) -> TrainState {
    let pattern = resolve_pattern(spec).unwrap();
    let density = if spec == "dense" { 1.0 } else { 0.25 };
    let mut rng = Rng::new(seed);
    let mask = pattern.init_mask(ROWS, COLS, density, &mut rng).unwrap();
    let w: Vec<f32> = (0..ROWS * COLS).map(|_| rng.normal()).collect();
    let mut vals = HashMap::new();
    vals.insert("mask.fc".to_string(), Tensor::from_f32(&[ROWS, COLS], mask.bits.clone()));
    vals.insert("param.fc.w".to_string(), Tensor::from_f32(&[ROWS, COLS], w));
    vals.insert("hard_flags".to_string(), Tensor::from_f32(&[1], vec![1.0]));
    if with_perm {
        let idx: Vec<i32> = rng.permutation(COLS).iter().map(|&p| p as i32).collect();
        vals.insert("perm_idx.fc".to_string(), Tensor::from_i32(&[COLS], idx));
    }
    TrainState { vals, site_names: vec!["fc".to_string()], budgets: vec![mask.nnz()] }
}

fn session(spec: &str, threads: usize, backend: Backend, with_perm: bool) -> SessionCtx {
    let state = state_for(spec, 5, with_perm);
    let perm = resolve_perm(if with_perm { "random" } else { "none" }).unwrap();
    SessionCtx::from_state("test", &state, resolve_pattern(spec).unwrap(), perm, threads, backend)
        .unwrap()
}

fn infer_line(id: &str, site: &str, batch: usize, x: &[f32], more: bool) -> String {
    Request::Infer { id: id.into(), site: site.into(), batch, x: x.to_vec(), more }.to_line()
}

fn parse_responses(out: &[u8]) -> Vec<Response> {
    std::str::from_utf8(out)
        .unwrap()
        .trim_end()
        .lines()
        .map(|l| Response::parse_line(l).unwrap())
        .collect()
}

/// A multi-burst script: `n_bursts` coalesced pairs, inputs seeded
/// per-connection so the two connections ask different questions.
fn script_for(seed: u64, n_bursts: usize) -> (String, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let mut script = String::new();
    let mut inputs = Vec::new();
    for b in 0..n_bursts {
        let x1: Vec<f32> = (0..COLS).map(|_| rng.normal()).collect();
        let x2: Vec<f32> = (0..2 * COLS).map(|_| rng.normal()).collect();
        script.push_str(&infer_line(&format!("s{seed}-b{b}-0"), "fc", 1, &x1, true));
        script.push('\n');
        script.push_str(&infer_line(&format!("s{seed}-b{b}-1"), "fc", 2, &x2, false));
        script.push('\n');
        inputs.push(x1);
        inputs.push(x2);
    }
    (script, inputs)
}

fn infer_bits(resp: &[Response]) -> Vec<(String, Vec<u32>)> {
    resp.iter()
        .map(|r| match r {
            Response::Infer { id, y, .. } => {
                (id.clone(), y.iter().map(|v| v.to_bits()).collect())
            }
            other => panic!("unexpected response {other:?}"),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Tentpole acceptance: 2 concurrent connections == sequential, to_bits-exact
// ---------------------------------------------------------------------------

#[test]
fn two_connections_interleaved_are_bit_identical_to_sequential() {
    for &backend in Backend::all() {
        for threads in [1usize, 4] {
            let ctx = session("diag:4", threads, backend, true);
            let (script_a, _) = script_for(100, 4);
            let (script_b, _) = script_for(200, 4);
            // Concurrent leg: two connection views over the SAME shared
            // plans, started together so their bursts interleave on the
            // kernel layer.
            let barrier = Barrier::new(2);
            let (out_a, out_b) = std::thread::scope(|s| {
                let run = |script: &str| {
                    let mut conn = ctx.connection();
                    let mut out = Vec::new();
                    barrier.wait();
                    serve(&mut conn, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
                    out
                };
                let ha = s.spawn(|| run(&script_a));
                let hb = s.spawn(|| run(&script_b));
                (ha.join().unwrap(), hb.join().unwrap())
            });
            // Sequential leg: a fresh session serving the same scripts
            // one after the other.
            let mut seq = session("diag:4", threads, backend, true);
            let mut seq_a = Vec::new();
            serve(&mut seq, script_a.as_bytes(), &mut seq_a, &NodeOpts::default()).unwrap();
            let mut seq_b = Vec::new();
            serve(&mut seq, script_b.as_bytes(), &mut seq_b, &NodeOpts::default()).unwrap();
            assert_eq!(
                infer_bits(&parse_responses(&out_a)),
                infer_bits(&parse_responses(&seq_a)),
                "connection A diverged (backend={backend:?} threads={threads})"
            );
            assert_eq!(
                infer_bits(&parse_responses(&out_b)),
                infer_bits(&parse_responses(&seq_b)),
                "connection B diverged (backend={backend:?} threads={threads})"
            );
        }
    }
}

#[test]
fn split_thread_budgets_stay_bit_identical() {
    // The socket listener hands each connection threads_per_conn(total,
    // conns) kernel threads; the split must never change results.
    let x: Vec<f32> = (0..3 * COLS).map(|i| (i as f32).sin()).collect();
    let ctx = session("block:8", 4, Backend::Tiled, false);
    let full: Vec<u32> = {
        let mut c = ctx.connection();
        c.run("fc", &x, 3).unwrap().iter().map(|v| v.to_bits()).collect()
    };
    for conns in [1usize, 2, 4, 8] {
        let t = padst::kernels::threads_per_conn(4, conns);
        assert!(t >= 1);
        let mut c = ctx.connection().with_threads(t);
        let got: Vec<u32> = c.run("fc", &x, 3).unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, full, "threads_per_conn(4, {conns}) = {t} changed results");
    }
}

// ---------------------------------------------------------------------------
// Satellite bugfix: NodeObs registration de-duplicates across connections
// ---------------------------------------------------------------------------

#[test]
fn node_obs_registration_dedups_across_connections() {
    let ctx = session("diag:4", 1, Backend::Scalar, false);
    let x: Vec<f32> = vec![0.5; COLS];
    let script = format!("{}\n", infer_line("a", "fc", 1, &x, false));
    // First connection registers the node metrics (cold).
    let mut c1 = ctx.connection();
    let mut out = Vec::new();
    serve(&mut c1, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    let regs_after_first = ctx.obs().registrations();
    let frames_after_first = ctx.obs().histogram("serve.frame_ns").snapshot().count;
    // Every later connection must resolve the SAME handles: zero new
    // registrations (the pre-fix failure mode double-registered or
    // clobbered the histograms) and aggregated recording.
    for i in 0..3 {
        let mut c = ctx.connection();
        let mut out = Vec::new();
        serve(&mut c, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
        assert_eq!(
            ctx.obs().registrations(),
            regs_after_first,
            "connection {} re-registered node metrics",
            i + 2
        );
    }
    let frames = ctx.obs().histogram("serve.frame_ns").snapshot().count;
    assert_eq!(
        frames,
        frames_after_first * 4,
        "per-connection frame recordings must aggregate, not clobber"
    );
    let errors = ctx.obs().counter("serve.error_frames").get();
    assert_eq!(errors, 0);
}

#[test]
fn warm_fingerprint_holds_on_every_connection() {
    let ctx = session("diag:4", 2, Backend::Scalar, true);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..2 * COLS).map(|_| rng.normal()).collect();
    // Prime the shared registry so connection 1's cold pass is the only
    // registration event.
    let mut warmup = ctx.connection();
    warmup.run("fc", &x, 2).unwrap();
    for conn_no in 0..3 {
        let mut c = ctx.connection();
        c.run("fc", &x, 2).unwrap(); // cold: sizes this view's scratch
        let fp = c.fingerprint();
        for round in 0..3 {
            c.run("fc", &x, 2).unwrap();
            assert_eq!(
                c.fingerprint(),
                fp,
                "connection {conn_no} warm round {round} allocated"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Hot reload: shared swap reaches live connections; CheckpointWatch polls
// ---------------------------------------------------------------------------

#[test]
fn reload_on_one_connection_reaches_the_other() {
    let ctx = session("diag:4", 1, Backend::Scalar, true);
    let mut rng = Rng::new(21);
    let x: Vec<f32> = (0..COLS).map(|_| rng.normal()).collect();
    let mut a = ctx.connection();
    let mut b = ctx.connection();
    let before: Vec<f32> = b.run("fc", &x, 1).unwrap().to_vec();
    assert_eq!(b.generation(), 1);
    // Connection A reloads different weights; B must see them at its
    // next burst without any explicit action.
    a.reload(&state_for("diag:4", 77, true)).unwrap();
    assert_eq!(a.generation(), 2);
    let after: Vec<f32> = b.run("fc", &x, 1).unwrap().to_vec();
    assert_eq!(b.generation(), 2, "the reload must reach the live connection");
    assert_ne!(before, after, "connection B kept serving the old plans");
}

#[test]
fn checkpoint_watch_reloads_on_mtime_change_only() {
    let dir = std::env::temp_dir().join(format!("padst_watch_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("state.tnz");
    checkpoint::save(&ckpt, &state_for("diag:4", 5, true)).unwrap();
    let mut ctx = SessionCtx::load_checkpoint(
        &ckpt,
        resolve_pattern("diag:4").unwrap(),
        resolve_perm("random").unwrap(),
        1,
        Backend::Scalar,
    )
    .unwrap();
    let mut rng = Rng::new(33);
    let x: Vec<f32> = (0..COLS).map(|_| rng.normal()).collect();
    let before: Vec<f32> = ctx.run("fc", &x, 1).unwrap().to_vec();

    let mut watch = CheckpointWatch::new(&ckpt);
    // Unchanged mtime: no reload, generation stays.
    assert_eq!(watch.poll(ctx.shared()).unwrap(), None);
    assert_eq!(ctx.generation(), 1);
    // Rewrite the checkpoint with different weights; the short sleep
    // guarantees a distinct mtime even on coarse-timestamp filesystems.
    std::thread::sleep(std::time::Duration::from_millis(25));
    checkpoint::save(&ckpt, &state_for("diag:4", 77, true)).unwrap();
    let gen = watch.poll(ctx.shared()).unwrap();
    assert_eq!(gen, Some(2), "an mtime change must hot-reload the shared plans");
    // The live view picks the swap up at its next run.
    let after: Vec<f32> = ctx.run("fc", &x, 1).unwrap().to_vec();
    assert_eq!(ctx.generation(), 2);
    assert_ne!(before, after, "the watcher reload did not reach the serving path");
    // And the poll is edge-triggered: no further reload without a change.
    assert_eq!(watch.poll(ctx.shared()).unwrap(), None);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_checkpoint_keeps_old_plans_serving() {
    let dir = std::env::temp_dir().join(format!("padst_watch_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("state.tnz");
    checkpoint::save(&ckpt, &state_for("diag:4", 5, true)).unwrap();
    let mut ctx = SessionCtx::load_checkpoint(
        &ckpt,
        resolve_pattern("diag:4").unwrap(),
        resolve_perm("random").unwrap(),
        1,
        Backend::Scalar,
    )
    .unwrap();
    let x: Vec<f32> = vec![0.5; COLS];
    let before: Vec<f32> = ctx.run("fc", &x, 1).unwrap().to_vec();
    let mut watch = CheckpointWatch::new(&ckpt);
    // A half-written checkpoint (the trainer mid-save): the poll fails,
    // the old plans keep serving, and the watermark is NOT advanced — a
    // later good write still triggers the reload.
    std::thread::sleep(std::time::Duration::from_millis(25));
    std::fs::write(&ckpt, b"not a checkpoint").unwrap();
    assert!(watch.poll(ctx.shared()).is_err());
    assert_eq!(ctx.generation(), 1);
    assert_eq!(ctx.run("fc", &x, 1).unwrap().to_vec(), before);
    // The good write lands; the same watch recovers.
    std::thread::sleep(std::time::Duration::from_millis(25));
    checkpoint::save(&ckpt, &state_for("diag:4", 77, true)).unwrap();
    assert_eq!(watch.poll(ctx.shared()).unwrap(), Some(2));
    assert_ne!(ctx.run("fc", &x, 1).unwrap().to_vec(), before);
    std::fs::remove_dir_all(&dir).ok();
}
