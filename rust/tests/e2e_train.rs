//! End-to-end integration: short PA-DST training runs through the real
//! artifacts, asserting the coordinator's externally visible contract —
//! loss decreases, DST keeps masks in-family with a fixed budget,
//! hardening is monotone and switches layers to re-indexing, and the
//! no-perm / random / learned modes all drive to completion.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially) if the manifest is missing so `cargo test` works in a fresh
//! checkout.

use std::path::Path;

use padst::coordinator::{RunConfig, Trainer};
use padst::perm::model::resolve_perm;
use padst::runtime::Runtime;
use padst::sparsity::pattern::resolve_pattern;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(&dir).unwrap())
}

fn short_cfg(perm: &str, spec: &str) -> RunConfig {
    RunConfig {
        model: "vit_tiny".into(),
        pattern: resolve_pattern(spec).unwrap(),
        density: 0.2,
        perm: resolve_perm(perm).unwrap(),
        steps: 30,
        dst_every: 10,
        eval_every: 0,
        harden_threshold: 0.22,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn learned_perm_run_trains_and_logs_penalties() {
    let Some(mut rt) = runtime() else { return };
    let res = Trainer::new(&mut rt, short_cfg("learned", "diag"))
        .run()
        .unwrap();
    assert_eq!(res.losses.len(), 30);
    assert!(res.losses.iter().all(|l| l.is_finite()));
    // Penalties recorded for every site at every step, strictly positive
    // until hardening.
    for (s, hist) in res.penalties.iter().enumerate() {
        assert_eq!(hist.len(), 30, "site {s}");
        assert!(hist[0] > 0.0, "site {s} initial penalty not positive");
    }
    // Penalty must decrease under the AutoShuffle regulariser.
    let first = res.penalties[0][0];
    let last = res.penalties[0][29];
    assert!(
        last < first,
        "penalty did not decrease: {first} -> {last}"
    );
    // Loss trend down (average of first vs last third).
    let third = res.losses.len() / 3;
    let head: f32 = res.losses[..third].iter().sum::<f32>() / third as f32;
    let tail: f32 = res.losses[res.losses.len() - third..].iter().sum::<f32>() / third as f32;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
}

fn noperm_and_random_modes_run_impl(rt: &mut Runtime) {
    for perm in ["none", "random"] {
        let res = Trainer::new(rt, short_cfg(perm, "diag"))
            .run()
            .unwrap();
        assert!(res.final_eval_loss.is_finite(), "{perm}");
        // No hardening events in non-learned modes.
        assert!(res.harden_step.iter().all(|h| h.is_none()), "{perm}");
    }
}

fn dst_runs_impl(rt: &mut Runtime) {
    for spec in ["diag", "block", "nm", "unstructured"] {
        let mut cfg = short_cfg("learned", spec);
        cfg.steps = 22; // crosses two DST events
        let res = Trainer::new(rt, cfg).run().unwrap();
        assert!(
            res.losses.iter().all(|l| l.is_finite()),
            "{spec}: non-finite loss"
        );
        // (mask family validation happens inside the trainer after every
        // dst_update; reaching here means it passed.)
    }
}

/// Parameterised specs drive the same end-to-end path: init masks come
/// from the typed params, and the trainer's per-step validation runs
/// against the *spec's* geometry (an artifact DST update that falls back
/// to the default template is rolled back, not crashed on).
fn parameterised_spec_runs_impl(rt: &mut Runtime) {
    for spec in ["block:4", "nm:1:4"] {
        let mut cfg = short_cfg("learned", spec);
        cfg.steps = 22;
        let res = Trainer::new(rt, cfg).run().unwrap();
        assert!(
            res.losses.iter().all(|l| l.is_finite()),
            "{spec}: non-finite loss"
        );
    }
}

fn forced_hardening_impl(rt: &mut Runtime) {
    let mut cfg = short_cfg("learned", "diag");
    // Threshold above any achievable normalised penalty: every layer
    // hardens after the controller's patience (3 observations).
    cfg.harden_threshold = 1e9;
    cfg.steps = 20;
    let res = Trainer::new(rt, cfg).run().unwrap();
    assert!(
        res.harden_step.iter().all(|h| h.is_some()),
        "not all sites hardened: {:?}",
        res.harden_step
    );
    // After hardening the recorded penalty becomes exactly 0 (the cond's
    // hard branch) — check the step after each site's harden event.
    for (i, h) in res.harden_step.iter().enumerate() {
        let s = h.unwrap();
        if s + 1 < res.penalties[i].len() {
            assert_eq!(res.penalties[i][s + 1], 0.0, "site {i}");
        }
    }
}

fn spec_hardening_overrides_impl(rt: &mut Runtime) {
    // A patience=/threshold= param on the perm spec wins over the config:
    // patience=1 with an unreachable threshold hardens every site on its
    // first observation instead of the default debounce of 3.
    let mut cfg = short_cfg("learned:patience=1:threshold=1000000000", "diag");
    cfg.steps = 5;
    let res = Trainer::new(rt, cfg).run().unwrap();
    assert!(
        res.harden_step.iter().all(|h| *h == Some(0)),
        "spec patience=1 did not harden at step 0: {:?}",
        res.harden_step
    );
}

fn seeds_reproducible_impl(rt: &mut Runtime) {
    let a = Trainer::new(rt, short_cfg("learned", "diag"))
        .run()
        .unwrap();
    let b = Trainer::new(rt, short_cfg("learned", "diag"))
        .run()
        .unwrap();
    assert_eq!(a.losses, b.losses, "same seed must give identical runs");
}

/// One umbrella test so all scenarios share a single Runtime's executable
/// cache — the per-test compile cost otherwise dominates the suite.
#[test]
fn e2e_scenarios() {
    let Some(mut rt) = runtime() else { return };
    noperm_and_random_modes_run_impl(&mut rt);
    dst_runs_impl(&mut rt);
    parameterised_spec_runs_impl(&mut rt);
    forced_hardening_impl(&mut rt);
    spec_hardening_overrides_impl(&mut rt);
    seeds_reproducible_impl(&mut rt);
}
