//! Integration tests for the kernel autotuner (`padst::kernels::tune`):
//! tuning-table persistence and merge algebra, tuned-dispatch bit-identity
//! against directly invoking the selected variant, the corrupt/stale-table
//! fallback, and the `PADST_TUNE=off` escape hatch.
//!
//! Tests that install into the process-wide [`tuner()`] serialise on a
//! local mutex and clear the table (and re-enable tuning) before they
//! return — integration tests in one file share a process, and cargo runs
//! them on threads.  Assertions about the table *backend* winning are
//! additionally gated on `PADST_BACKEND` being unset, so the suite still
//! passes under CI's `PADST_BACKEND=scalar` re-run (where the backend is
//! pinned by design).

use std::path::PathBuf;
use std::sync::Mutex;

use padst::kernels::micro::Backend;
use padst::kernels::tune::{
    self, candidates, tuner, Choice, TuneBudget, TuneEntry, TuneKey, TuningTable,
};
use padst::kernels::{run_plan, run_plan_mt, run_plan_mt_tuned, run_plan_tuned};
use padst::sparsity::pattern::{resolve_pattern, KernelPlan};
use padst::util::Rng;

/// Serialises every test that touches the process-wide tuner.
static TUNER_LOCK: Mutex<()> = Mutex::new(());

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("padst_tune_{tag}_{}", std::process::id()))
}

/// One small plan per kind (dims divisible by the block size 16).
fn test_plans() -> Vec<(&'static str, KernelPlan)> {
    let (rows, cols) = (48usize, 64usize);
    let mut rng = Rng::new(0x7E5);
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    ["diag", "block", "unstructured", "dense"]
        .iter()
        .map(|spec| {
            let pattern = resolve_pattern(spec).unwrap();
            let mask = pattern.init_mask(rows, cols, 0.2, &mut rng).unwrap();
            (*spec, pattern.compress(&w, &mask, None))
        })
        .collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (p, (va, vb)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            va.to_bits(),
            vb.to_bits(),
            "{what}: element {p} differs ({va} vs {vb})"
        );
    }
}

fn entry(choice: Choice, ns: u64) -> TuneEntry {
    TuneEntry { choice, best_ns: ns, reps: 3 }
}

// ------------------------------------------------------------ persistence

#[test]
fn table_round_trips_through_disk() {
    let plans = test_plans();
    let mut table = TuningTable::new();
    for (i, (_, plan)) in plans.iter().enumerate() {
        for &threads in &[1usize, 2] {
            let key = TuneKey::of_plan(plan, threads);
            let choice = Choice { backend: Backend::Scalar, batched: false, max_threads: 0 };
            table.insert(key, entry(choice, 100 + i as u64));
        }
    }
    assert!(!table.is_empty());

    let dir = tmp("roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("table.json");
    table.save(&path).unwrap();
    let loaded = TuningTable::load(&path).unwrap();
    assert_eq!(table, loaded, "save -> load must be the identity");
    // load_lenient on the same file agrees; on a missing file it is empty.
    assert_eq!(TuningTable::load_lenient(&path), table);
    assert!(TuningTable::load_lenient(&dir.join("absent.json")).is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn merge_is_associative_and_keeps_better_entries() {
    let plans = test_plans();
    let keys: Vec<TuneKey> = plans.iter().map(|(_, plan)| TuneKey::of_plan(plan, 1)).collect();
    let scalar = Choice { backend: Backend::Scalar, batched: false, max_threads: 0 };
    let tiled = Choice { backend: Backend::Tiled, batched: false, max_threads: 0 };

    let mut a = TuningTable::new();
    a.insert(keys[0], entry(scalar, 300));
    a.insert(keys[1], entry(scalar, 100));
    let mut b = TuningTable::new();
    b.insert(keys[0], entry(tiled, 200)); // better than a's 300
    b.insert(keys[2], entry(tiled, 50));
    let mut c = TuningTable::new();
    c.insert(keys[1], entry(tiled, 400)); // worse than a's 100
    c.insert(keys[3], entry(scalar, 70));

    let mut ab_c = a.clone();
    ab_c.merge(&b);
    ab_c.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);
    assert_eq!(ab_c, a_bc, "merge must be associative");

    assert_eq!(ab_c.get(&keys[0]).unwrap().best_ns, 200, "better entry wins");
    assert_eq!(ab_c.get(&keys[1]).unwrap().best_ns, 100, "worse entry loses");
    assert_eq!(ab_c.len(), 4);
}

#[test]
fn corrupt_and_stale_tables_fall_back() {
    let dir = tmp("corrupt");
    std::fs::create_dir_all(&dir).unwrap();

    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "{ not json").unwrap();
    assert!(TuningTable::load(&garbage).is_err());
    assert!(TuningTable::load_lenient(&garbage).is_empty());

    let stale = dir.join("stale.json");
    std::fs::write(&stale, r#"{"tune_schema":99,"entries":{}}"#).unwrap();
    let err = TuningTable::load(&stale).unwrap_err().to_string();
    assert!(err.contains("tune_schema"), "stale-schema error names the schema: {err}");
    assert!(TuningTable::load_lenient(&stale).is_empty());

    let bad_key = dir.join("bad_key.json");
    std::fs::write(&bad_key, r#"{"tune_schema":1,"entries":{"huh":{}}}"#).unwrap();
    assert!(TuningTable::load(&bad_key).is_err());
    assert!(TuningTable::load_lenient(&bad_key).is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------- tuned dispatch

/// The acceptance contract: with a table installed, `run_plan` /
/// `run_plan_mt` output is bit-identical to directly invoking the variant
/// the tuner resolved — for every test-grid key and every candidate
/// choice.  Candidates whose backend matches the caller's must also
/// bit-reproduce the untuned dispatch (the batched/thread-cap axes are
/// bit-preserving by construction).
#[test]
fn tuned_dispatch_is_bit_identical_to_direct_choice() {
    let _g = TUNER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let plans = test_plans();
    let (rows, batch, cols) = (48usize, 5usize, 64usize);
    let mut rng = Rng::new(0xD15);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let backend = Backend::default_backend();

    for (spec, plan) in &plans {
        for threads in [1usize, 2] {
            let key = TuneKey::of_plan(plan, threads);
            // Untuned reference for this (plan, threads).
            tuner().clear();
            let mut y_untuned = vec![f32::NAN; batch * rows];
            run_plan_mt(plan, &x, batch, &mut y_untuned, threads, backend);

            for cand in candidates(key.kind, threads) {
                let mut table = TuningTable::new();
                table.insert(key, entry(cand, 1));
                tuner().install(table);

                let (choice, hit) = tuner().choice_for(plan, threads, backend);
                assert!(hit, "{spec} t={threads}: installed key must hit");

                let mut y_tuned = vec![f32::NAN; batch * rows];
                run_plan_mt(plan, &x, batch, &mut y_tuned, threads, backend);
                let mut y_direct = vec![f32::NAN; batch * rows];
                run_plan_mt_tuned(plan, &x, batch, &mut y_direct, threads, &choice);
                assert_bits_eq(
                    &y_tuned,
                    &y_direct,
                    &format!("{spec} t={threads} cand={cand:?}: tuned vs direct"),
                );
                if choice.backend == backend {
                    assert_bits_eq(
                        &y_tuned,
                        &y_untuned,
                        &format!("{spec} t={threads} cand={cand:?}: tuned vs untuned"),
                    );
                }
            }
        }
        // Serial entry point keys the table at threads=1.
        let key = TuneKey::of_plan(plan, 1);
        let cand = Choice { backend, batched: key.kind == tune::PlanKind::Rows, max_threads: 0 };
        let mut table = TuningTable::new();
        table.insert(key, entry(cand, 1));
        tuner().install(table);
        let (choice, hit) = tuner().choice_for(plan, 1, backend);
        assert!(hit);
        let mut y_tuned = vec![f32::NAN; batch * rows];
        run_plan(plan, &x, batch, &mut y_tuned, backend);
        let mut y_direct = vec![f32::NAN; batch * rows];
        run_plan_tuned(plan, &x, batch, &mut y_direct, &choice);
        assert_bits_eq(&y_tuned, &y_direct, &format!("{spec} serial: tuned vs direct"));
    }
    tuner().clear();
}

/// Precedence: an unpinned caller on the process default backend takes the
/// table's backend; an explicitly threaded-through non-default backend
/// keeps its own.  Skipped when `PADST_BACKEND` pins the backend (CI's
/// scalar re-run) — the pinning path itself is covered by unit tests in
/// `kernels::tune`.
#[test]
fn table_backend_wins_only_when_unpinned() {
    let _g = TUNER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if std::env::var("PADST_BACKEND").is_ok() || tune::backend_pinned() {
        eprintln!("skipping: backend is pinned in this process");
        return;
    }
    let plans = test_plans();
    let plan = &plans[0].1;
    let key = TuneKey::of_plan(plan, 1);
    let other = match Backend::default_backend() {
        Backend::Scalar => Backend::Tiled,
        _ => Backend::Scalar,
    };
    let mut table = TuningTable::new();
    table.insert(key, entry(Choice { backend: other, batched: false, max_threads: 0 }, 1));
    tuner().install(table);

    // Unpinned caller on the default backend: the table's backend applies.
    let (choice, hit) = tuner().choice_for(plan, 1, Backend::default_backend());
    assert!(hit);
    assert_eq!(choice.backend, other, "table backend applies when unpinned");

    // Caller explicitly on a non-default backend: the caller wins, only
    // the bit-preserving axes come from the table.
    let (choice, hit) = tuner().choice_for(plan, 1, other);
    assert!(hit);
    assert_eq!(choice.backend, other, "explicit caller backend is kept");
    tuner().clear();
}

/// Disabling tuning (`PADST_TUNE=off` / `set_enabled(false)`) must
/// bit-reproduce the untuned dispatch even with a table installed.
#[test]
fn tune_off_bit_reproduces_untuned() {
    let _g = TUNER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let plans = test_plans();
    let (rows, batch, cols) = (48usize, 5usize, 64usize);
    let mut rng = Rng::new(0x0FF);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let backend = Backend::default_backend();

    tuner().clear();
    tuner().set_enabled(true);
    let mut y_untuned = vec![f32::NAN; batch * rows];
    run_plan_mt(&plans[0].1, &x, batch, &mut y_untuned, 2, backend);

    let key = TuneKey::of_plan(&plans[0].1, 2);
    let mut table = TuningTable::new();
    table.insert(key, entry(Choice { backend, batched: true, max_threads: 1 }, 1));
    tuner().install(table);
    tuner().set_enabled(false);
    assert!(!tuner().enabled());
    let (choice, hit) = tuner().choice_for(&plans[0].1, 2, backend);
    assert!(!hit, "no table hits while tuning is off");
    assert_eq!(choice, Choice::default_for(backend));

    let mut y_off = vec![f32::NAN; batch * rows];
    run_plan_mt(&plans[0].1, &x, batch, &mut y_off, 2, backend);
    assert_bits_eq(&y_untuned, &y_off, "tune off vs untuned");

    tuner().set_enabled(true);
    tuner().clear();
}

// ----------------------------------------------------------- measurement

/// End-to-end `tune_plan`: the winner is one of the advertised candidates,
/// its key matches the plan, and dispatching it is deterministic.
#[test]
fn tune_plan_winner_is_a_candidate_and_dispatches_deterministically() {
    let plans = test_plans();
    let (rows, batch, cols) = (48usize, 5usize, 64usize);
    let mut rng = Rng::new(0x7E0);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f32; batch * rows];
    let budget = TuneBudget { min_reps: 1, max_reps: 2, budget_ns: 1 };

    for (spec, plan) in &plans {
        let (key, won) = tune::tune_plan(plan, &x, batch, &mut y, 1, &budget);
        assert_eq!(key, TuneKey::of_plan(plan, 1), "{spec}: key matches the plan");
        assert!(
            candidates(key.kind, 1).contains(&won.choice),
            "{spec}: winner {:?} must be an advertised candidate",
            won.choice
        );
        assert!(won.reps >= 1);
        let mut y1 = vec![f32::NAN; batch * rows];
        run_plan_mt_tuned(plan, &x, batch, &mut y1, 1, &won.choice);
        let mut y2 = vec![f32::NAN; batch * rows];
        run_plan_mt_tuned(plan, &x, batch, &mut y2, 1, &won.choice);
        assert_bits_eq(&y1, &y2, &format!("{spec}: winner dispatch is deterministic"));
    }
}

/// Cross-backend numeric tolerance is a property of the microkernels, not
/// the tuner: outputs under scalar and tiled dispatch stay elementwise
/// close whether or not a table re-routed the call.
#[test]
fn cross_backend_tolerance_unchanged_by_tuning() {
    let _g = TUNER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    tuner().clear();
    let plans = test_plans();
    let (rows, batch, cols) = (48usize, 5usize, 64usize);
    let mut rng = Rng::new(0x70E);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();

    for (spec, plan) in &plans {
        let mut y_scalar = vec![f32::NAN; batch * rows];
        run_plan_mt(plan, &x, batch, &mut y_scalar, 2, Backend::Scalar);

        // Re-route through the table: same key, tiled backend, batched on
        // Rows plans — the dispatch path the tuner would pick.
        let key = TuneKey::of_plan(plan, 2);
        let cand = Choice {
            backend: Backend::Tiled,
            batched: key.kind == tune::PlanKind::Rows,
            max_threads: 0,
        };
        let mut table = TuningTable::new();
        table.insert(key, entry(cand, 1));
        tuner().install(table);
        let (choice, _) = tuner().choice_for(plan, 2, Backend::Tiled);
        let mut y_tuned = vec![f32::NAN; batch * rows];
        run_plan_mt_tuned(plan, &x, batch, &mut y_tuned, 2, &choice);
        tuner().clear();

        for (p, (a, b)) in y_scalar.iter().zip(&y_tuned).enumerate() {
            let scale = a.abs().max(b.abs()).max(1.0);
            assert!(
                (a - b).abs() <= 1e-4 * scale,
                "{spec}: element {p} drifted across backends ({a} vs {b})"
            );
        }
    }
}
