//! Harness subsystem contract tests — all runnable without artifacts or a
//! PJRT backend, because the executor/journal/telemetry layers are generic
//! over the cell type:
//!
//! * sharded execution returns results in input order, identical to the
//!   sequential (1-worker) path, for any worker count;
//! * every cell runs exactly once, per-worker contexts are built once per
//!   worker, and errors abort the pool;
//! * a killed sweep resumes from the JSONL journal without re-running
//!   completed cells, including a torn (mid-write) trailing record;
//! * `BenchRecord`/`BenchReport` round-trip through `util::json`, and the
//!   baseline diff flags an injected p50 regression.
//!
//! The end-to-end shard-vs-sequential sweep equality (real `run_sweep` vs
//! `run_sweep_sharded` through artifacts) lives at the bottom and skips
//! when `artifacts/manifest.json` is absent, like the other integration
//! tests.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use padst::coordinator::sweep::{self, SweepShardOpts};
use padst::harness::baseline::compare;
use padst::harness::executor::execute_sharded;
use padst::harness::shard::{merge_journals, plan_cells, read_journal, CellKey, Journal, META_KEY};
use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::micro::Backend;
use padst::runtime::Runtime;
use padst::util::json;
use padst::util::stats::summarize;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("padst_harness_{tag}_{}", std::process::id()))
}

// ---------------------------------------------------------------- executor

#[test]
fn sharded_matches_sequential_for_any_worker_count() {
    let keys: Vec<usize> = (0..23).collect();
    let work = |_: &mut (), i: usize, k: &usize| -> anyhow::Result<(usize, usize)> {
        Ok((i, k * k))
    };
    let seq = execute_sharded(&keys, 1, |_| Ok(()), work).unwrap();
    assert_eq!(seq.len(), keys.len());
    for workers in [2, 4, 16, 64] {
        let par = execute_sharded(&keys, workers, |_| Ok(()), work).unwrap();
        assert_eq!(par, seq, "workers={workers}");
    }
}

#[test]
fn every_cell_runs_exactly_once_on_its_own_worker_context() {
    let keys: Vec<usize> = (0..50).collect();
    let runs = AtomicUsize::new(0);
    let inits = AtomicUsize::new(0);
    let out = execute_sharded(
        &keys,
        8,
        |wid| -> anyhow::Result<usize> {
            inits.fetch_add(1, Ordering::SeqCst);
            Ok(wid)
        },
        |wid: &mut usize, _i: usize, k: &usize| -> anyhow::Result<(usize, usize)> {
            runs.fetch_add(1, Ordering::SeqCst);
            Ok((*wid, *k))
        },
    )
    .unwrap();
    assert_eq!(runs.load(Ordering::SeqCst), keys.len());
    assert_eq!(inits.load(Ordering::SeqCst), 8);
    // Results are in key order regardless of which worker computed them.
    assert_eq!(out.iter().map(|&(_, k)| k).collect::<Vec<_>>(), keys);
    // Every worker id that pulled cells was a real pool member.
    assert!(out.iter().all(|&(w, _)| w < 8));
}

#[test]
fn worker_error_aborts_and_surfaces() {
    let keys: Vec<usize> = (0..64).collect();
    let err = execute_sharded(
        &keys,
        4,
        |_| Ok(()),
        |_: &mut (), _i: usize, k: &usize| -> anyhow::Result<usize> {
            if *k == 17 {
                anyhow::bail!("cell {k} exploded");
            }
            Ok(*k)
        },
    )
    .unwrap_err();
    assert!(err.to_string().contains("exploded"), "{err}");
}

// ----------------------------------------------------------------- journal

#[test]
fn journal_resume_skips_completed_cells_and_survives_torn_writes() {
    let dir = scratch("journal");
    std::fs::remove_dir_all(&dir).ok();
    // Parent directories don't exist yet — Journal::open must create them.
    let path = dir.join("nested").join("sweep.jsonl");

    // First run: two cells complete, then the process dies mid-write.
    {
        let (j, done) = Journal::open(&path).unwrap();
        assert!(done.is_empty());
        j.record("A@0.6", &json::num(1.0)).unwrap();
        j.record("A@0.9", &json::num(2.0)).unwrap();
    }
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"key\":\"B@0.6\",\"cell\":").unwrap(); // torn record
    }

    // Resume: the torn record is discarded, the completed cells are back.
    let (j, done) = Journal::open(&path).unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(done["A@0.6"].as_f64(), Some(1.0));
    assert_eq!(done["A@0.9"].as_f64(), Some(2.0));

    // Only the missing cells are pending.
    let all = plan_cells(&[("A", true), ("B", true)], &[0.6, 0.9]);
    let pending: Vec<String> = all
        .iter()
        .map(CellKey::id)
        .filter(|id| !done.contains_key(id))
        .collect();
    assert_eq!(pending, ["B@0.6", "B@0.9"]);

    // Appending after the torn tail still yields parseable lines.
    j.record("B@0.6", &json::num(3.0)).unwrap();
    let (_j2, done2) = Journal::open(&path).unwrap();
    assert_eq!(done2.len(), 3);
    assert_eq!(done2["B@0.6"].as_f64(), Some(3.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn journal_records_safely_from_worker_threads() {
    let dir = scratch("journal_mt");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("sweep.jsonl");
    let (j, _) = Journal::open(&path).unwrap();
    let jref = &j;
    let keys: Vec<usize> = (0..40).collect();
    execute_sharded(
        &keys,
        8,
        |_| Ok(()),
        |_: &mut (), _i: usize, k: &usize| -> anyhow::Result<()> {
            jref.record(&format!("cell@{k}"), &json::num(*k as f64))
        },
    )
    .unwrap();
    let (_j2, done) = Journal::open(&path).unwrap();
    assert_eq!(done.len(), 40);
    for k in 0..40 {
        assert_eq!(done[&format!("cell@{k}")].as_f64(), Some(k as f64));
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------- journal-merge

/// Two shard journals with the same header merge into one journal that a
/// resume run can consume: header preserved, cells unioned, duplicate
/// cell ids resolved first-wins.
#[test]
fn journal_merge_combines_shards() {
    let dir = scratch("journal_merge");
    std::fs::remove_dir_all(&dir).ok();
    let meta = json::obj(vec![("model", json::s("vit_tiny")), ("steps", json::num(10.0))]);

    let shard0 = dir.join("shard0.jsonl");
    {
        let (j, _) = Journal::open(&shard0).unwrap();
        j.record(META_KEY, &meta).unwrap();
        j.record("A@0.6", &json::num(1.0)).unwrap();
        j.record("B@0.6", &json::num(2.0)).unwrap();
    }
    let shard1 = dir.join("shard1.jsonl");
    {
        let (j, _) = Journal::open(&shard1).unwrap();
        j.record(META_KEY, &meta).unwrap();
        j.record("A@0.9", &json::num(3.0)).unwrap();
        // Duplicate of shard0's cell with a different payload: the first
        // input's copy must win.
        j.record("A@0.6", &json::num(99.0)).unwrap();
    }

    let out = dir.join("merged.jsonl");
    let n = merge_journals(&[shard0.clone(), shard1.clone()], &out).unwrap();
    assert_eq!(n, 3);

    let merged = read_journal(&out).unwrap();
    assert_eq!(merged[META_KEY], meta);
    assert_eq!(merged["A@0.6"].as_f64(), Some(1.0), "first occurrence wins");
    assert_eq!(merged["B@0.6"].as_f64(), Some(2.0));
    assert_eq!(merged["A@0.9"].as_f64(), Some(3.0));

    // The merged journal reopens through the normal Journal path (what a
    // final `padst sweep --journal merged.jsonl` run does).
    let (_j, done) = Journal::open(&out).unwrap();
    assert_eq!(done.len(), 4); // 3 cells + header
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn journal_merge_refuses_mismatched_or_headerless_inputs() {
    let dir = scratch("journal_merge_bad");
    std::fs::remove_dir_all(&dir).ok();

    let a = dir.join("a.jsonl");
    {
        let (j, _) = Journal::open(&a).unwrap();
        j.record(META_KEY, &json::obj(vec![("model", json::s("vit_tiny"))])).unwrap();
        j.record("A@0.6", &json::num(1.0)).unwrap();
    }
    let b = dir.join("b.jsonl");
    {
        let (j, _) = Journal::open(&b).unwrap();
        j.record(META_KEY, &json::obj(vec![("model", json::s("gpt_tiny"))])).unwrap();
    }
    let headerless = dir.join("c.jsonl");
    {
        let (j, _) = Journal::open(&headerless).unwrap();
        j.record("A@0.9", &json::num(2.0)).unwrap();
    }
    let out = dir.join("merged.jsonl");

    let e = merge_journals(&[a.clone(), b], &out).unwrap_err();
    assert!(e.to_string().contains("different sweep"), "{e}");
    let e = merge_journals(&[a.clone(), headerless], &out).unwrap_err();
    assert!(e.to_string().contains("no __meta__ header"), "{e}");
    let e = merge_journals(&[a], &dir.join("m2.jsonl"));
    assert!(e.is_ok(), "single-input merge is a normalising copy");
    let e = merge_journals(&[], &out).unwrap_err();
    assert!(e.to_string().contains("at least one"), "{e}");
    let e = merge_journals(&[dir.join("missing.jsonl")], &out).unwrap_err();
    assert!(e.to_string().contains("reading journal"), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------------------------------------------- telemetry

#[test]
fn bench_report_roundtrips_through_json_text() {
    let s = summarize(&[1.0, 2.0, 3.0, 4.0, 100.0]);
    let mut report = BenchReport::new("kernels", 4);
    report.push(
        BenchRecord::from_summary("microbench", "gather(64,768,768) d=0.1", &s)
            .with_metric("gflops", 12.5)
            .with_metric("vs_naive", 2.0),
    );
    report.push(BenchRecord::value("memory", "vit_tiny/+PA-DST").with_metric("state_mb", 1.25));
    let text = report.to_json().to_string_pretty();
    let back = BenchReport::parse(&text).unwrap();
    assert_eq!(back, report);
}

#[test]
fn bench_report_write_load_creates_parents_and_replaces_atomically() {
    let dir = scratch("bench");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("deep").join("BENCH_x.json");
    let mut report = BenchReport::new("x", 1);
    report.push(BenchRecord::value("g", "n").with_metric("v", 1.0));
    report.write(&path).unwrap();
    assert_eq!(BenchReport::load(&path).unwrap(), report);
    report.push(BenchRecord::value("g", "n2").with_metric("v", 2.0));
    report.write(&path).unwrap();
    assert_eq!(BenchReport::load(&path).unwrap(), report);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_compare_gates_on_injected_regression() {
    let with_p50 = |p50: f64| {
        let mut r = BenchReport::new("kernels", 2);
        r.push(BenchRecord::from_summary("microbench", "hot", &summarize(&[p50, p50])));
        r
    };
    let old = with_p50(1.0);
    assert!(!compare(&old, &with_p50(1.04), 10.0).regressed());
    let c = compare(&old, &with_p50(1.5), 10.0); // injected +50% regression
    assert!(c.regressed());
    assert_eq!(c.regressions[0].id, "microbench/hot");
    assert!((c.regressions[0].pct - 50.0).abs() < 1e-9);
}

/// The sweep journal is parameter-checked: a journal written under one
/// (model, steps, seed) must refuse to resume a different sweep.  Runs
/// without artifacts — the metadata check happens before any runtime is
/// opened (the first call fails at manifest load, *after* writing the
/// journal header).
#[test]
fn sweep_journal_refuses_other_parameters() {
    let dir = scratch("journal_meta");
    std::fs::remove_dir_all(&dir).ok();
    let no_artifacts = dir.join("no_artifacts_here");
    let journal = dir.join("journal.jsonl");
    let methods = vec![sweep::method_by_name("DynaDiag").unwrap()];
    let opts = SweepShardOpts {
        workers: 1,
        threads: 1,
        journal: Some(journal.clone()),
        verbose: false,
        ..Default::default()
    };
    // First run: header is journaled, then the missing manifest errors.
    let e1 = sweep::run_sweep_sharded(&no_artifacts, "vit_tiny", &methods, &[0.9], 10, 7, &opts)
        .unwrap_err();
    assert!(e1.to_string().contains("manifest"), "{e1}");
    assert!(journal.exists());
    // Same parameters: resumes past the header, fails at the manifest again.
    let e2 = sweep::run_sweep_sharded(&no_artifacts, "vit_tiny", &methods, &[0.9], 10, 7, &opts)
        .unwrap_err();
    assert!(e2.to_string().contains("manifest"), "{e2}");
    // Different steps: refused before any execution.
    let e3 = sweep::run_sweep_sharded(&no_artifacts, "vit_tiny", &methods, &[0.9], 20, 7, &opts)
        .unwrap_err();
    assert!(e3.to_string().contains("different sweep"), "{e3}");
    // Different model: also refused.
    let e4 = sweep::run_sweep_sharded(&no_artifacts, "gpt_tiny", &methods, &[0.9], 10, 7, &opts)
        .unwrap_err();
    assert!(e4.to_string().contains("different sweep"), "{e4}");
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- end-to-end (needs PJRT)

/// `run_sweep` with 1 worker and N workers must produce identical cell
/// results on a small grid.  Requires artifacts + the real backend; skips
/// (passes trivially) otherwise, like the other integration tests.
#[test]
fn sweep_sharded_equals_sequential_on_small_grid() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let methods: Vec<_> = ["DynaDiag", "DynaDiag+PA", "Dense"]
        .iter()
        .map(|n| sweep::method_by_name(n).unwrap())
        .collect();
    let sparsities = [0.8, 0.95];
    let steps = 20;

    let mut rt = Runtime::open(&dir).unwrap();
    let seq = sweep::run_sweep(
        &mut rt,
        "vit_tiny",
        &methods,
        &sparsities,
        steps,
        7,
        false,
        1,
        Backend::default_backend(),
    )
    .unwrap();

    let journal = scratch("sweep_equality").join("journal.jsonl");
    std::fs::remove_file(&journal).ok();
    let opts = SweepShardOpts {
        workers: 3,
        threads: 3,
        journal: Some(journal.clone()),
        verbose: false,
        ..Default::default()
    };
    let par =
        sweep::run_sweep_sharded(&dir, "vit_tiny", &methods, &sparsities, steps, 7, &opts).unwrap();

    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.method, b.method);
        assert_eq!(a.sparsity, b.sparsity);
        // Deterministic fields must agree bitwise; train_seconds is
        // wall-clock and legitimately differs.
        assert_eq!(a.result.losses, b.result.losses, "{}@{}", a.method, a.sparsity);
        assert_eq!(a.result.final_eval_loss, b.result.final_eval_loss);
        assert_eq!(a.result.final_eval_acc, b.result.final_eval_acc);
        assert_eq!(a.result.final_ppl, b.result.final_ppl);
        assert_eq!(a.result.harden_step, b.result.harden_step);
    }

    // Re-running with the journal present recomputes nothing (the journal
    // already covers the whole grid) and still returns the same cells.
    // Line count = one metadata header + one line per cell.
    let runs_before = std::fs::read_to_string(&journal).unwrap().lines().count();
    assert_eq!(runs_before, par.len() + 1);
    let resumed =
        sweep::run_sweep_sharded(&dir, "vit_tiny", &methods, &sparsities, steps, 7, &opts).unwrap();
    let runs_after = std::fs::read_to_string(&journal).unwrap().lines().count();
    assert_eq!(runs_after, runs_before, "resume re-ran journaled cells");
    for (a, b) in par.iter().zip(&resumed) {
        assert_eq!(a.result.final_eval_loss, b.result.final_eval_loss);
    }
    std::fs::remove_dir_all(scratch("sweep_equality")).ok();
}

// A compile-time guard: the executor accepts non-Send worker contexts
// (what lets sweep workers own a `Runtime`, which holds `Rc`s).
#[test]
fn executor_accepts_non_send_worker_contexts() {
    use std::rc::Rc;
    let keys = vec![1usize, 2, 3];
    let out = execute_sharded(
        &keys,
        2,
        |_| Ok(Rc::new(10usize)),
        |ctx: &mut Rc<usize>, _i: usize, k: &usize| -> anyhow::Result<usize> { Ok(**ctx + *k) },
    )
    .unwrap();
    assert_eq!(out, [11, 12, 13]);
}
