//! ISSUE 7 test surface for the observability layer: histogram quantiles
//! against a sorted-vec oracle, snapshot merge algebra, span-stack
//! balance, the journal heartbeat lane (new readers see it, pre-PR-7
//! readers skip it), the serve warm-path zero-allocation contract with
//! metrics enabled, and the `obs_schema` provenance stamp on
//! histogram-sourced bench records.

use std::sync::Arc;

use padst::harness::shard::{self, Journal, META_KEY};
use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::micro::Backend;
use padst::obs::watch::{self, Heartbeat, HEARTBEAT_KEY, PLAN_KEY};
use padst::obs::{self, span, HistSnapshot, Histogram, MetricRegistry, OBS_SCHEMA_VERSION};
use padst::serve::{serve, NodeOpts, Request, SessionCtx};
use padst::util::json;
use padst::util::Rng;

// ---------------------------------------------------------------------------
// Satellite (test plan a): quantiles vs the sorted-vec oracle
// ---------------------------------------------------------------------------

#[test]
fn histogram_quantiles_match_sorted_vec_oracle() {
    // Samples spanning ~9 orders of magnitude, like nanosecond timings.
    // The log buckets guarantee a representative within half a bucket
    // width of the true rank value: exact below 16, 6.25 % above.
    let mut rng = Rng::new(11);
    let h = Histogram::default();
    let mut vals: Vec<u64> = Vec::new();
    for _ in 0..5000 {
        let v = (rng.below(1_000_000) as u64) * (1 + rng.below(4000) as u64);
        h.record(v);
        vals.push(v);
    }
    vals.sort_unstable();
    let snap = h.snapshot();
    assert_eq!(snap.count, 5000);
    for &q in &[0.0, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
        // Same rank convention as util::stats::summarize.
        let oracle = vals[((vals.len() - 1) as f64 * q).round() as usize];
        let est = snap.quantile(q);
        let err = est.abs_diff(oracle) as f64;
        assert!(err <= 1.0 + 0.0625 * oracle as f64, "q={q} oracle={oracle} est={est}");
    }
    assert_eq!(snap.min, vals[0]);
    assert_eq!(snap.max, *vals.last().unwrap());
    assert_eq!(snap.sum, vals.iter().sum::<u64>());
}

// ---------------------------------------------------------------------------
// Snapshot merge algebra: associative, commutative, == combined recording
// ---------------------------------------------------------------------------

#[test]
fn hist_snapshot_merge_is_associative_and_matches_combined_recording() {
    let streams: [&[u64]; 3] = [&[1, 2, 3, 700], &[16, 17, 40_000], &[0, 5, 5, 1 << 33]];
    let combined = Histogram::default();
    let parts: Vec<HistSnapshot> = streams
        .iter()
        .map(|s| {
            let h = Histogram::default();
            for &v in *s {
                h.record(v);
                combined.record(v);
            }
            h.snapshot()
        })
        .collect();
    let mut ab_c = parts[0].clone();
    ab_c.merge(&parts[1]);
    ab_c.merge(&parts[2]);
    let mut bc = parts[1].clone();
    bc.merge(&parts[2]);
    let mut a_bc = parts[0].clone();
    a_bc.merge(&bc);
    assert_eq!(ab_c, a_bc, "merge must be associative");
    assert_eq!(ab_c, combined.snapshot(), "merged shards must equal one combined stream");
}

#[test]
fn registry_snapshots_merge_like_one_registry() {
    // Counters add, gauges keep the max (high-water on the wire),
    // histogram buckets add — the journal-merge contract.
    let (a, b, both) = (MetricRegistry::new(), MetricRegistry::new(), MetricRegistry::new());
    a.counter("n").add(3);
    b.counter("n").add(4);
    both.counter("n").add(7);
    a.gauge("q").set_max(7);
    b.gauge("q").set_max(5);
    both.gauge("q").set_max(7);
    for v in [3u64, 9, 27] {
        a.histogram("h").record(v);
        both.histogram("h").record(v);
    }
    for v in [81u64, 243] {
        b.histogram("h").record(v);
        both.histogram("h").record(v);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    assert_eq!(merged, both.snapshot());
    let mut rev = b.snapshot();
    rev.merge(&a.snapshot());
    assert_eq!(rev, merged, "merge must commute");
}

// ---------------------------------------------------------------------------
// Span stack: balanced under nesting and early return, timed on both paths
// ---------------------------------------------------------------------------

#[test]
fn span_stack_balances_and_records_through_early_returns() {
    fn risky(h: &Arc<Histogram>, fail: bool) -> Result<(), ()> {
        let _outer = span::timed("outer", h);
        let _inner = span::enter("inner");
        assert_eq!(span::path(), "outer/inner");
        if fail {
            return Err(());
        }
        Ok(())
    }
    let h = Arc::new(Histogram::default());
    assert_eq!(span::depth(), 0);
    assert!(risky(&h, true).is_err());
    assert_eq!(span::depth(), 0, "early return must unwind the span stack");
    assert!(risky(&h, false).is_ok());
    assert_eq!(span::depth(), 0);
    assert_eq!(h.count(), 2, "the timed span records on both exit paths");
}

// ---------------------------------------------------------------------------
// Journal heartbeat lane: round-trips for new readers, invisible to old ones
// ---------------------------------------------------------------------------

#[test]
fn journal_heartbeats_round_trip_and_old_readers_skip_them() {
    let dir = std::env::temp_dir().join(format!("padst_obs_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("journal.jsonl");
    std::fs::remove_file(&path).ok();

    let (j, done) = Journal::open(&path).unwrap();
    assert!(done.is_empty());
    j.record(META_KEY, &json::obj(vec![("model", json::s("vit_tiny"))])).unwrap();
    j.record("RigL@0.8", &json::obj(vec![("train_seconds", json::num(2.5))])).unwrap();
    let hb = Heartbeat {
        worker: 1,
        event: "done".to_string(),
        cell: "RigL@0.8".to_string(),
        done: 1,
        total: 2,
        t: 1000.0,
        dur_s: Some(2.5),
    };
    j.append_event(HEARTBEAT_KEY, &hb.to_json()).unwrap();
    let plan = json::obj(vec![
        ("cells", json::arr([json::s("RigL@0.8"), json::s("RigL@0.9")])),
        ("total", json::num(2.0)),
    ]);
    j.append_event(PLAN_KEY, &plan).unwrap();
    drop(j);

    // New reader: the watch view sees cells, heartbeats and the plan.
    let view = watch::read_view(&path).unwrap();
    assert_eq!(view.heartbeats, vec![hb]);
    assert_eq!(view.plan_total, Some(2));
    assert_eq!(view.total(), Some(2));
    assert_eq!(view.done.len(), 1);
    assert_eq!(view.skipped, 0, "every line must be a recognised record kind");
    assert_eq!(view.durations_s(), vec![2.5]);

    // Pre-PR-7 readers key on "key"/"cell" and must skip the event lane.
    let records = shard::read_journal(&path).unwrap();
    assert_eq!(records.len(), 2, "events must be invisible to the record map");
    assert!(records.contains_key(META_KEY));
    assert!(records.contains_key("RigL@0.8"));
    let (_j2, done2) = Journal::open(&path).unwrap();
    assert_eq!(done2.len(), 2, "resume must ignore heartbeat/plan events");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watch_renders_progress_and_eta_from_a_heartbeat_journal() {
    let text = [
        r#"{"cell":{"model":"vit_tiny","seed":0,"steps":5},"key":"__meta__"}"#,
        r#"{"plan":{"cells":["RigL@0.8","RigL@0.9","SET@0.8","SET@0.9"],"total":4}}"#,
        r#"{"cell":{"train_seconds":30},"key":"RigL@0.8"}"#,
        r#"{"hb":{"cell":"RigL@0.8","done":1,"dur_s":30,"event":"done","t":900,"total":4,"worker":0}}"#,
        r#"{"hb":{"cell":"RigL@0.9","done":1,"event":"start","t":995,"total":4,"worker":0}}"#,
    ]
    .join("\n");
    let view = watch::parse_view(&text);
    let frame = watch::render(&view, 1000.0, 120.0);
    assert!(frame.contains("model=vit_tiny steps=5 seed=0"), "{frame}");
    assert!(frame.contains("1/4 done (25.0%)"), "{frame}");
    assert!(frame.contains("eta:"), "{frame}");
    assert!(frame.contains("running RigL@0.9"), "{frame}");
    assert!(!frame.contains("STALE"), "{frame}");
    // Same inputs, same bytes: the golden contract.
    assert_eq!(frame, watch::render(&view, 1000.0, 120.0));
}

// ---------------------------------------------------------------------------
// Serve warm path: zero-allocation fingerprint holds with metrics enabled
// ---------------------------------------------------------------------------

#[test]
fn serve_warm_path_stays_zero_alloc_with_metrics_enabled() {
    obs::set_enabled(true);
    let mut ctx = SessionCtx::synthetic("diag:4", 8, 8, 0.5, 1, Backend::Scalar).unwrap();
    let infer = |id: &str| {
        Request::Infer {
            id: id.into(),
            site: "demo".into(),
            batch: 1,
            x: vec![1.0; 8],
            more: false,
        }
        .to_line()
    };
    let stats = |id: &str| Request::Stats { id: id.into() }.to_line();
    // Cold pass: plans compile, scratch sizes, node + span metrics register.
    let script = format!("{}\n{}\n", infer("cold"), stats("s0"));
    let mut out = Vec::new();
    serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    let fp = ctx.fingerprint();
    // Warm passes: recording into existing handles must neither allocate
    // scratch nor register metrics — the fingerprint carries both.
    for round in 0..3 {
        let script = format!("{}\n{}\n{}\n", infer("w1"), infer("w2"), stats("s1"));
        let mut out = Vec::new();
        serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
        assert_eq!(
            ctx.fingerprint(),
            fp,
            "warm serve pass {round} allocated or registered with metrics enabled"
        );
    }
    let snap = ctx.obs_snapshot();
    let frames = snap.hists.get("serve.frame_ns").expect("frame latency histogram");
    assert!(frames.count >= 8, "every frame must be timed (saw {})", frames.count);
}

// ---------------------------------------------------------------------------
// Provenance: histogram-sourced bench records carry obs_schema
// ---------------------------------------------------------------------------

#[test]
fn bench_record_from_hist_stamps_obs_schema_and_round_trips() {
    let h = Histogram::default();
    for v in [1_000u64, 2_000, 3_000, 4_000, 5_000] {
        h.record(v);
    }
    let r = BenchRecord::from_hist("serve", "session infer_ns (obs)", &h.snapshot());
    assert_eq!(r.obs_schema, OBS_SCHEMA_VERSION);
    assert_eq!(r.n, 5);
    assert!(r.p50_s > 0.0 && r.p90_s >= r.p50_s, "p50={} p90={}", r.p50_s, r.p90_s);

    let mut rep = BenchReport::new("obs_test", 1);
    rep.push(r);
    let rep = rep.with_obs(json::obj(vec![("obs_schema", json::num(1.0))]));
    let text = rep.to_json().to_string_pretty();
    let back = BenchReport::parse(&text).unwrap();
    assert_eq!(back.records[0].obs_schema, OBS_SCHEMA_VERSION);
    assert!((back.records[0].p90_s - rep.records[0].p90_s).abs() < 1e-12);
    assert!(back.obs.is_some(), "report-level obs must survive the round trip");

    // A summary-sourced record has no obs provenance.
    let plain = BenchRecord::value("g", "v");
    assert_eq!(plain.obs_schema, 0);
}
