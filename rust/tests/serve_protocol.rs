//! ISSUE 6 test surface for `padst serve` (extended for the protocol v2
//! binary wire in ISSUE 10): the wire-format codec round-trips (NDJSON
//! and length-prefixed binary, `to_bits`-exact incl. NaN/±inf), the
//! corrupt-frame containment tables for both formats, the batching
//! bit-identity contract (batch-of-N == N singles, `to_bits`-exact per
//! backend x thread count x plan kind, across wire formats), the
//! `SessionCtx` warm-path allocation guard with reload eviction, the
//! `hello` wire negotiation, and the serving-path geometry errors.
//! Cross-connection behaviour lives in `serve_concurrent.rs`.

use std::collections::HashMap;

use padst::coordinator::{checkpoint, TrainState};
use padst::kernels::micro::Backend;
use padst::perm::model::resolve_perm;
use padst::serve::{
    decode_binary_body, encode_binary_infer, read_frame, serve, BinaryFrame, NodeOpts, Request,
    Response, ServeWireStats, SessionCtx, SiteInfo, WireFrame, BINARY_MAGIC, PROTOCOL_VERSION,
};
use padst::sparsity::pattern::resolve_pattern;
use padst::tensor::Tensor;
use padst::util::json::Json;
use padst::util::Rng;

const ROWS: usize = 32;
const COLS: usize = 64;

/// A one-site `TrainState` over `spec` with random weights and
/// (optionally) a random hard permutation — the checkpoint shape
/// `padst serve` loads.  32x64 satisfies every swept spec's
/// divisibility: block:8 | nm:2:8 | diag:4 | unstructured | dense.
fn state_for(spec: &str, seed: u64, with_perm: bool) -> TrainState {
    let pattern = resolve_pattern(spec).unwrap();
    let density = if spec == "dense" { 1.0 } else { 0.25 };
    let mut rng = Rng::new(seed);
    let mask = pattern.init_mask(ROWS, COLS, density, &mut rng).unwrap();
    let w: Vec<f32> = (0..ROWS * COLS).map(|_| rng.normal()).collect();
    let mut vals = HashMap::new();
    vals.insert("mask.fc".to_string(), Tensor::from_f32(&[ROWS, COLS], mask.bits.clone()));
    vals.insert("param.fc.w".to_string(), Tensor::from_f32(&[ROWS, COLS], w));
    vals.insert("hard_flags".to_string(), Tensor::from_f32(&[1], vec![1.0]));
    if with_perm {
        let idx: Vec<i32> = rng.permutation(COLS).iter().map(|&p| p as i32).collect();
        vals.insert("perm_idx.fc".to_string(), Tensor::from_i32(&[COLS], idx));
    }
    TrainState { vals, site_names: vec!["fc".to_string()], budgets: vec![mask.nnz()] }
}

fn session(spec: &str, threads: usize, backend: Backend, with_perm: bool) -> SessionCtx {
    let state = state_for(spec, 5, with_perm);
    let perm = resolve_perm(if with_perm { "random" } else { "none" }).unwrap();
    SessionCtx::from_state("test", &state, resolve_pattern(spec).unwrap(), perm, threads, backend)
        .unwrap()
}

fn infer_line(id: &str, site: &str, batch: usize, x: &[f32], more: bool) -> String {
    Request::Infer { id: id.into(), site: site.into(), batch, x: x.to_vec(), more }.to_line()
}

fn parse_responses(out: &[u8]) -> Vec<Response> {
    std::str::from_utf8(out)
        .unwrap()
        .trim_end()
        .lines()
        .map(|l| Response::parse_line(l).unwrap())
        .collect()
}

// ---------------------------------------------------------------------------
// Satellite (a): codec round-trip + corrupt-frame table
// ---------------------------------------------------------------------------

#[test]
fn codec_round_trips_every_variant() {
    let requests = vec![
        Request::Infer {
            id: "r1".into(),
            site: "fc".into(),
            batch: 2,
            x: vec![0.5, -1.25, 3.0, f32::MIN_POSITIVE, 1.0e-7, 123456.78],
            more: true,
        },
        Request::Infer { id: "r2".into(), site: "fc".into(), batch: 1, x: vec![1.0], more: false },
        Request::Info { id: "r3".into() },
        Request::Reload { id: "r4".into(), checkpoint: Some("run.tnz".into()) },
        Request::Reload { id: "r5".into(), checkpoint: None },
        Request::Stats { id: "r6".into() },
        Request::Hello { id: "r7".into(), wire: Some("binary".into()) },
        Request::Hello { id: "r8".into(), wire: None },
    ];
    for r in requests {
        assert_eq!(Request::parse_line(&r.to_line()).unwrap(), r, "{r:?}");
    }
    let responses = vec![
        Response::Infer { id: "r1".into(), batch: 2, y: vec![0.1, -2.5, 1.0e-30, 7.0] },
        Response::Info {
            id: "r3".into(),
            model: "ckpt.tnz".into(),
            generation: 3,
            sites: vec![SiteInfo {
                name: "fc".into(),
                rows: 32,
                cols: 64,
                nnz: 256,
                driver: "gather".into(),
                permuted: true,
            }],
            stats: Some(ServeWireStats {
                requests: 3,
                responses: 2,
                errors: 0,
                batches: 1,
                widest_batch: 2,
            }),
        },
        Response::Reloaded { id: "r4".into(), generation: 4 },
        Response::Stats { id: "r6".into(), stats: ServeWireStats::default(), obs: Json::Null },
        Response::Hello { id: "r7".into(), proto: PROTOCOL_VERSION, wire: "binary".into() },
        Response::Error { id: Some("r9".into()), error: "unknown site \"zz\"".into() },
        Response::Error { id: None, error: "bad frame: unexpected end of JSON".into() },
    ];
    for r in responses {
        assert_eq!(Response::parse_line(&r.to_line()).unwrap(), r, "{r:?}");
    }
}

#[test]
fn f32_values_survive_the_wire_bitwise() {
    // f32 -> f64 is exact and the serializer round-trips f64, so wire
    // transport preserves f32 bits (the protocol-doc claim).
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..257)
        .map(|_| rng.normal() * 10f32.powi(rng.below(20) as i32 - 10))
        .collect();
    let r =
        Request::Infer { id: "w".into(), site: "fc".into(), batch: 1, x: x.clone(), more: false };
    match Request::parse_line(&r.to_line()).unwrap() {
        Request::Infer { x: back, .. } => {
            let a: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn corrupt_frames_yield_error_frames_never_exit() {
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    // (line, expected echoed id, substring expected in the error)
    let cases: &[(&str, Option<&str>, &str)] = &[
        (r#"{"v":1,"op":"infer","id":"t""#, None, "bad frame"),
        ("not json", None, "bad frame"),
        (r#"{"v":1,"op":"warp","id":"u"}"#, Some("u"), "unknown op"),
        (r#"{"v":9,"op":"info","id":"w"}"#, Some("w"), "unsupported protocol version"),
        (r#"{"op":"info","id":"n"}"#, Some("n"), "no \"v\""),
        (r#"{"v":1,"op":"infer","id":"m"}"#, Some("m"), "\"site\""),
        ("[1,2,3]", None, "no \"v\""),
    ];
    let script: String = cases.iter().map(|(l, _, _)| format!("{l}\n")).collect();
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.requests, cases.len());
    assert_eq!(stats.errors, cases.len());
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim_end().lines().collect();
    assert_eq!(lines.len(), cases.len());
    for ((line, want_id, want_msg), resp) in cases.iter().zip(&lines) {
        let v = Json::parse(resp).unwrap_or_else(|e| panic!("error frame not JSON: {e}"));
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{line}");
        assert_eq!(v.get("id").and_then(Json::as_str), *want_id, "{line}");
        let err = v.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains(want_msg), "{line}: {err}");
    }
}

// ---------------------------------------------------------------------------
// Satellite (b): batch-of-N == N singles, to_bits-exact
// ---------------------------------------------------------------------------

#[test]
fn batched_equals_singles_bitwise() {
    // One spec per KernelPlan kind: block:8 -> Blocks, diag:4/nm:2:8
    // (hard-permuted) -> Rows, unstructured -> Csr, dense -> Dense.
    let batches = [1usize, 2, 5];
    for &spec in &["block:8", "nm:2:8", "diag:4", "unstructured", "dense"] {
        let with_perm = matches!(spec, "nm:2:8" | "diag:4" | "unstructured");
        for &backend in Backend::all() {
            for threads in [1usize, 4] {
                let mut ctx = session(spec, threads, backend, with_perm);
                let mut rng = Rng::new(99);
                let parts: Vec<(Vec<f32>, usize)> = batches
                    .iter()
                    .map(|&b| ((0..b * COLS).map(|_| rng.normal()).collect(), b))
                    .collect();
                let mut singles: Vec<u32> = Vec::new();
                for (x, b) in &parts {
                    let y = ctx.run("fc", x, *b).unwrap();
                    singles.extend(y.iter().map(|v| v.to_bits()));
                }
                let refs: Vec<(&[f32], usize)> =
                    parts.iter().map(|(x, b)| (x.as_slice(), *b)).collect();
                let batched: Vec<u32> = ctx
                    .run_coalesced("fc", &refs)
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(
                    batched, singles,
                    "batch-of-N != N singles for spec={spec} backend={backend:?} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn wire_batched_matches_wire_singles() {
    // Same identity, through the full node: a "more":true pair answered
    // from ONE coalesced dispatch must be bit-equal to the pair sent as
    // independent requests.
    let mut rng = Rng::new(7);
    let x1: Vec<f32> = (0..COLS).map(|_| rng.normal()).collect();
    let x2: Vec<f32> = (0..2 * COLS).map(|_| rng.normal()).collect();
    let batched = format!(
        "{}\n{}\n",
        infer_line("a", "fc", 1, &x1, true),
        infer_line("b", "fc", 2, &x2, false)
    );
    let singles = format!(
        "{}\n{}\n",
        infer_line("a", "fc", 1, &x1, false),
        infer_line("b", "fc", 2, &x2, false)
    );
    let run = |script: &str| {
        let mut ctx = session("diag:4", 2, Backend::Tiled, true);
        let mut out = Vec::new();
        let stats = serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
        (parse_responses(&out), stats)
    };
    let (a, a_stats) = run(&batched);
    let (b, b_stats) = run(&singles);
    assert_eq!(a_stats.batches, 1, "the more:true pair must coalesce into one dispatch");
    assert_eq!(a_stats.widest_batch, 2);
    assert_eq!(b_stats.batches, 2);
    let bits = |resp: &[Response]| -> Vec<(String, usize, Vec<u32>)> {
        resp.iter()
            .map(|r| match r {
                Response::Infer { id, batch, y } => {
                    (id.clone(), *batch, y.iter().map(|v| v.to_bits()).collect())
                }
                other => panic!("unexpected response {other:?}"),
            })
            .collect()
    };
    assert_eq!(bits(&a), bits(&b));
}

// ---------------------------------------------------------------------------
// Satellite (c): warm-path allocation guard + reload eviction
// ---------------------------------------------------------------------------

#[test]
fn warm_path_reuses_buffers_and_reload_evicts() {
    let mut ctx = session("diag:4", 1, Backend::Scalar, true);
    let mut rng = Rng::new(3);
    let x4: Vec<f32> = (0..4 * COLS).map(|_| rng.normal()).collect();
    let x1: Vec<f32> = x4[..COLS].to_vec();
    // The cold call sizes the scratch; every later same-or-smaller
    // request must reuse it byte-for-byte (the SinkhornScratch
    // buffer_fingerprint technique, one layer up).
    let y_before: Vec<f32> = ctx.run("fc", &x4, 4).unwrap().to_vec();
    let fp = ctx.fingerprint();
    for _ in 0..3 {
        ctx.run("fc", &x4, 4).unwrap();
        assert_eq!(ctx.fingerprint(), fp, "warm same-size request allocated");
        ctx.run("fc", &x1, 1).unwrap();
        assert_eq!(ctx.fingerprint(), fp, "warm smaller request allocated");
    }
    // Reload under a different seed: plans must be evicted (the
    // generation in the fingerprint ends the old one's validity) and the
    // outputs must change with the new weights/mask.
    ctx.reload(&state_for("diag:4", 77, true)).unwrap();
    assert_ne!(ctx.fingerprint(), fp, "reload must invalidate the warm fingerprint");
    let y_after: Vec<f32> = ctx.run("fc", &x4, 4).unwrap().to_vec();
    assert_ne!(y_before, y_after, "reload kept serving the old plans");
    let fp2 = ctx.fingerprint();
    ctx.run("fc", &x4, 4).unwrap();
    assert_eq!(ctx.fingerprint(), fp2, "post-reload warm path allocated");
}

#[test]
fn info_and_reload_frames_round_trip_through_a_checkpoint() {
    let dir = std::env::temp_dir().join(format!("padst_serve_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("state.tnz");
    checkpoint::save(&ckpt, &state_for("diag:4", 5, true)).unwrap();
    let mut ctx = SessionCtx::load_checkpoint(
        &ckpt,
        resolve_pattern("diag:4").unwrap(),
        resolve_perm("random").unwrap(),
        1,
        Backend::Scalar,
    )
    .unwrap();
    let script = format!(
        "{}\n{}\n{}\n",
        Request::Info { id: "i".into() }.to_line(),
        Request::Reload { id: "r".into(), checkpoint: None }.to_line(),
        Request::Info { id: "j".into() }.to_line(),
    );
    let mut out = Vec::new();
    serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    let resp = parse_responses(&out);
    match &resp[0] {
        Response::Info { id, generation, sites, .. } => {
            assert_eq!(id, "i");
            assert_eq!(*generation, 1);
            assert_eq!(sites.len(), 1);
            assert_eq!((sites[0].rows, sites[0].cols), (ROWS, COLS));
            assert!(sites[0].permuted, "the random perm must fold into the plan");
            assert_eq!(sites[0].driver, "gather");
        }
        other => panic!("{other:?}"),
    }
    match &resp[1] {
        Response::Reloaded { id, generation } => {
            assert_eq!(id, "r");
            assert_eq!(*generation, 2, "reload must bump the plan generation");
        }
        other => panic!("{other:?}"),
    }
    match &resp[2] {
        Response::Info { generation, .. } => assert_eq!(*generation, 2),
        other => panic!("{other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Satellite (d): infeasible geometry -> descriptive error frame, id echoed
// ---------------------------------------------------------------------------

#[test]
fn geometry_errors_echo_request_id_and_preserve_order() {
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let good: Vec<f32> = vec![0.5; COLS];
    let script = format!(
        "{}\n{}\n{}\n",
        infer_line("ok1", "fc", 1, &good, true),
        infer_line("bad-len", "fc", 1, &[1.0, 2.0, 3.0], false),
        infer_line("bad-site", "nope", 1, &good, false),
    );
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.responses, 3);
    assert_eq!(stats.errors, 2);
    let resp = parse_responses(&out);
    // The held "more":true burst flushed BEFORE the error frame, so
    // responses stay in request order.
    match &resp[0] {
        Response::Infer { id, .. } => assert_eq!(id, "ok1"),
        other => panic!("{other:?}"),
    }
    match &resp[1] {
        Response::Error { id, error } => {
            assert_eq!(id.as_deref(), Some("bad-len"));
            assert!(error.contains("expected batch x cols"), "{error}");
        }
        other => panic!("{other:?}"),
    }
    match &resp[2] {
        Response::Error { id, error } => {
            assert_eq!(id.as_deref(), Some("bad-site"));
            assert!(error.contains("known:"), "{error}");
            assert!(error.contains("fc"), "{error}");
        }
        other => panic!("{other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Stats frames: live counters + merged obs snapshot; info carries counters
// ---------------------------------------------------------------------------

#[test]
fn stats_frame_carries_counters_and_obs_snapshot() {
    use padst::obs::ObsSnapshot;
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let good: Vec<f32> = vec![0.5; COLS];
    let script = format!(
        "{}\n{}\n{}\n",
        infer_line("a", "fc", 1, &good, false),
        Request::Stats { id: "s".into() }.to_line(),
        Request::Info { id: "i".into() }.to_line(),
    );
    let mut out = Vec::new();
    serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    let resp = parse_responses(&out);
    match &resp[1] {
        Response::Stats { id, stats, obs } => {
            assert_eq!(id, "s");
            // Counters are read when the stats frame is handled: the
            // infer frame plus this one seen, only the infer answered.
            assert_eq!(stats.requests, 2);
            assert_eq!(stats.responses, 1);
            assert_eq!(stats.batches, 1);
            // The embedded snapshot is schema-versioned, parseable, and
            // carries the per-site infer histogram plus node metrics.
            let snap = ObsSnapshot::parse(obs).unwrap();
            let infer = snap.hists.get("serve.infer_ns.fc").expect("per-site infer histogram");
            assert_eq!(infer.count, 1);
            assert!(snap.hists.contains_key("serve.frame_ns"), "{:?}", snap.hists.keys());
            assert!(snap.hists.contains_key("serve.batch_rows"), "{:?}", snap.hists.keys());
        }
        other => panic!("{other:?}"),
    }
    // Satellite bugfix: info responses must include the live counters.
    match &resp[2] {
        Response::Info { id, stats: Some(s), .. } => {
            assert_eq!(id, "i");
            assert_eq!(s.requests, 3, "info must see all three frames");
            assert_eq!(s.responses, 2);
        }
        other => panic!("info must carry live ServeStats: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Node behaviour: EOF flush + the CI golden's arithmetic assumption
// ---------------------------------------------------------------------------

#[test]
fn eof_flushes_a_held_burst() {
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let line = infer_line("tail", "fc", 1, &[0.25; COLS], true);
    let mut out = Vec::new();
    let stats =
        serve(&mut ctx, format!("{line}\n").as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.responses, 1, "EOF must answer the held more:true frame");
    match &parse_responses(&out)[0] {
        Response::Infer { id, .. } => assert_eq!(id, "tail"),
        other => panic!("{other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Protocol v2: binary activation frames + hello negotiation (ISSUE 10)
// ---------------------------------------------------------------------------

/// Drain a mixed text/binary output stream into decoded frames.
enum OutFrame {
    Text(Response),
    Binary(BinaryFrame),
}

fn parse_mixed(out: &[u8]) -> Vec<OutFrame> {
    let mut cur = std::io::Cursor::new(out);
    let mut frames = Vec::new();
    loop {
        match read_frame(&mut cur).unwrap() {
            WireFrame::Eof => break,
            WireFrame::Text(l) => frames.push(OutFrame::Text(Response::parse_line(&l).unwrap())),
            WireFrame::Binary(b) => frames.push(OutFrame::Binary(decode_binary_body(&b).unwrap())),
            WireFrame::Corrupt(msg) => panic!("corrupt frame in node output: {msg}"),
        }
    }
    frames
}

#[test]
fn binary_codec_round_trips_bitwise_including_nan_and_inf() {
    // The payload is raw little-endian f32: NaN payload bits, signalling
    // NaNs, ±inf, signed zero and denormals must all survive exactly —
    // stronger than the text path (which flattens -0.0).
    let weird: Vec<f32> = vec![
        f32::NAN,
        f32::from_bits(0x7fc0_0001), // quiet NaN with payload
        f32::from_bits(0xffc0_dead), // negative NaN with payload
        f32::from_bits(0x7f80_0001), // signalling NaN
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        f32::from_bits(1), // smallest denormal
        1.5e-42,
        123456.78,
    ];
    let frame = encode_binary_infer("req-1", "fc", 3, &weird, true).unwrap();
    assert_eq!(&frame[..4], &BINARY_MAGIC);
    let mut cur = std::io::Cursor::new(frame.as_slice());
    let body = match read_frame(&mut cur).unwrap() {
        WireFrame::Binary(b) => b,
        other => panic!("{other:?}"),
    };
    match decode_binary_body(&body).unwrap() {
        BinaryFrame::InferRequest { id, site, batch, x, more } => {
            assert_eq!((id.as_str(), site.as_str(), batch, more), ("req-1", "fc", 3, true));
            let a: Vec<u32> = weird.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "binary payload must be to_bits-exact");
        }
        other => panic!("{other:?}"),
    }
    // Response direction too.
    let frame = padst::serve::encode_binary_infer_response("req-1", 3, &weird).unwrap();
    let mut cur = std::io::Cursor::new(frame.as_slice());
    let body = match read_frame(&mut cur).unwrap() {
        WireFrame::Binary(b) => b,
        other => panic!("{other:?}"),
    };
    match decode_binary_body(&body).unwrap() {
        BinaryFrame::InferResponse { id, batch, y } => {
            assert_eq!((id.as_str(), batch), ("req-1", 3));
            let a: Vec<u32> = weird.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn binary_wire_is_about_4_bytes_per_value() {
    // The fig3 acceptance bound: payload <= 5 bytes/value on the wire
    // (vs ~13 for NDJSON text numbers).
    let x = vec![0.123456f32; 4096];
    let frame = encode_binary_infer("r", "fc", 8, &x, false).unwrap();
    let per_value = frame.len() as f64 / x.len() as f64;
    assert!(per_value <= 5.0, "binary frame is {per_value:.3} bytes/value");
    let line = infer_line("r", "fc", 8, &x, false);
    assert!(
        line.len() > 2 * frame.len(),
        "text should be >2x the binary size (text {} vs binary {})",
        line.len(),
        frame.len()
    );
}

#[test]
fn binary_infer_serves_end_to_end_and_mirrors_the_format() {
    let mut ctx = session("diag:4", 2, Backend::Tiled, true);
    let mut rng = Rng::new(11);
    let x1: Vec<f32> = (0..COLS).map(|_| rng.normal()).collect();
    let x2: Vec<f32> = (0..2 * COLS).map(|_| rng.normal()).collect();
    // A binary "more" frame and a text closer coalesce into ONE dispatch;
    // each response mirrors its request's format.
    let mut script = encode_binary_infer("b1", "fc", 1, &x1, true).unwrap();
    script.extend_from_slice(format!("{}\n", infer_line("t1", "fc", 2, &x2, false)).as_bytes());
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_slice(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.batches, 1, "binary and text frames must coalesce together");
    let frames = parse_mixed(&out);
    assert_eq!(frames.len(), 2);
    let bin_y = match &frames[0] {
        OutFrame::Binary(BinaryFrame::InferResponse { id, batch, y }) => {
            assert_eq!((id.as_str(), *batch), ("b1", 1));
            y.clone()
        }
        _ => panic!("binary request must get a binary response"),
    };
    let text_y = match &frames[1] {
        OutFrame::Text(Response::Infer { id, batch, y }) => {
            assert_eq!((id.as_str(), *batch), ("t1", 2));
            y.clone()
        }
        _ => panic!("text request must get a text response"),
    };
    // Same inputs through the all-text path must agree bitwise.
    let mut ctx2 = session("diag:4", 2, Backend::Tiled, true);
    let script = format!(
        "{}\n{}\n",
        infer_line("b1", "fc", 1, &x1, true),
        infer_line("t1", "fc", 2, &x2, false)
    );
    let mut out2 = Vec::new();
    serve(&mut ctx2, script.as_bytes(), &mut out2, &NodeOpts::default()).unwrap();
    let resp = parse_responses(&out2);
    let (ref_y1, ref_y2) = match (&resp[0], &resp[1]) {
        (Response::Infer { y: a, .. }, Response::Infer { y: b, .. }) => (a.clone(), b.clone()),
        other => panic!("{other:?}"),
    };
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&bin_y), bits(&ref_y1), "binary wire changed the kernel result");
    assert_eq!(bits(&text_y), bits(&ref_y2));
}

#[test]
fn hello_negotiation_switches_text_requests_to_binary_responses() {
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let x: Vec<f32> = vec![0.5; COLS];
    let script = format!(
        "{}\n{}\n{}\n",
        Request::Hello { id: "h".into(), wire: Some("binary".into()) }.to_line(),
        infer_line("a", "fc", 1, &x, false),
        Request::Hello { id: "h2".into(), wire: Some("ndjson".into()) }.to_line(),
    );
    let mut out = Vec::new();
    serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    let frames = parse_mixed(&out);
    assert_eq!(frames.len(), 3);
    match &frames[0] {
        OutFrame::Text(Response::Hello { id, proto, wire }) => {
            assert_eq!((id.as_str(), *proto, wire.as_str()), ("h", PROTOCOL_VERSION, "binary"));
        }
        _ => panic!("hello ack must be a text frame"),
    }
    match &frames[1] {
        OutFrame::Binary(BinaryFrame::InferResponse { id, .. }) => assert_eq!(id, "a"),
        _ => panic!("after hello wire=binary, text infers must get binary responses"),
    }
    match &frames[2] {
        OutFrame::Text(Response::Hello { wire, .. }) => assert_eq!(wire, "ndjson"),
        _ => panic!("{:?}", "hello ack must be text"),
    }
    // Unknown wire names are an error frame, not a dead connection.
    let script = format!(
        "{}\n{}\n",
        Request::Hello { id: "h3".into(), wire: Some("carrier-pigeon".into()) }.to_line(),
        infer_line("b", "fc", 1, &x, false),
    );
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.responses, 2, "the connection must keep serving after a bad hello");
}

#[test]
fn corrupt_binary_framing_answers_an_error_frame_and_never_exits() {
    let x: Vec<f32> = vec![0.5; COLS];
    // Stream-desynchronising corruption: one error frame, connection
    // closes (frames after the corruption are NOT interpreted), process
    // lives (serve returns Ok).
    let bad_magic: Vec<u8> = {
        let mut f = vec![BINARY_MAGIC[0], b'X', b'Y', b'Z'];
        f.extend_from_slice(&8u32.to_le_bytes());
        f.extend_from_slice(&[0u8; 8]);
        f
    };
    let oversized: Vec<u8> = {
        let mut f = BINARY_MAGIC.to_vec();
        f.extend_from_slice(&u32::MAX.to_le_bytes());
        f
    };
    for (label, corrupt, want) in
        [("bad-magic", bad_magic, "bad binary frame magic"), ("oversized", oversized, "exceeds")]
    {
        let mut ctx = session("diag:4", 1, Backend::Scalar, false);
        let mut script = corrupt.clone();
        // A valid frame AFTER the corruption must not be served — the
        // stream cannot be trusted past the desync point.
        script.extend_from_slice(format!("{}\n", infer_line("late", "fc", 1, &x, false)).as_bytes());
        let mut out = Vec::new();
        let stats = serve(&mut ctx, script.as_slice(), &mut out, &NodeOpts::default()).unwrap();
        assert_eq!(stats.errors, 1, "{label}");
        assert_eq!(stats.responses, 1, "{label}: connection must close after the error frame");
        let resp = parse_responses(&out);
        match &resp[0] {
            Response::Error { id: None, error } => {
                assert!(error.contains(want), "{label}: {error}")
            }
            other => panic!("{label}: {other:?}"),
        }
    }
    // A length prefix promising more body bytes than the stream holds:
    // the truncation surfaces at EOF as one error frame, clean return.
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let truncated: Vec<u8> = {
        let mut f = BINARY_MAGIC.to_vec();
        f.extend_from_slice(&100u32.to_le_bytes());
        f.extend_from_slice(&[1u8, 0]); // promises 100 body bytes, sends 2
        f
    };
    let mut out = Vec::new();
    let stats = serve(&mut ctx, truncated.as_slice(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!((stats.errors, stats.responses), (1, 1));
    match &parse_responses(&out)[0] {
        Response::Error { id: None, error } => {
            assert!(error.contains("truncated"), "{error}");
            assert!(error.contains("100"), "the promised length should be named: {error}");
        }
        other => panic!("{other:?}"),
    }
    // In-sync body corruption (unknown kind): error frame, connection
    // KEEPS serving — the length prefix already delimited the damage.
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let mut script = {
        let mut f = BINARY_MAGIC.to_vec();
        f.extend_from_slice(&2u32.to_le_bytes());
        f.extend_from_slice(&[9u8, 0]); // kind 9 does not exist
        f
    };
    script.extend_from_slice(format!("{}\n", infer_line("after", "fc", 1, &x, false)).as_bytes());
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_slice(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.responses, 2, "an in-sync bad body must not close the connection");
    let frames = parse_mixed(&out);
    match &frames[0] {
        OutFrame::Text(Response::Error { error, .. }) => {
            assert!(error.contains("unknown binary frame kind"), "{error}")
        }
        _ => panic!("expected an error frame first"),
    }
    match &frames[1] {
        OutFrame::Text(Response::Infer { id, .. }) => assert_eq!(id, "after"),
        _ => panic!("the frame after the bad body must be served"),
    }
    // A client sending a server->client response kind: same containment.
    let mut ctx = session("diag:4", 1, Backend::Scalar, false);
    let script = padst::serve::encode_binary_infer_response("oops", 1, &[1.0]).unwrap();
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_slice(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!((stats.errors, stats.responses), (1, 1));
    match &parse_responses(&out)[0] {
        Response::Error { id, error } => {
            assert_eq!(id.as_deref(), Some("oops"), "the binary id must be echoed");
            assert!(error.contains("unexpected binary infer-response"), "{error}");
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn v1_text_frames_still_serve_unchanged() {
    // Back-compat leg of the v2 bump: a pre-binary client stamping v:1
    // gets served exactly as before (responses now stamped v:2).
    let mut ctx = SessionCtx::synthetic("diag:4", 8, 8, 0.5, 1, Backend::Scalar).unwrap();
    let script = concat!(
        r#"{"v":1,"op":"infer","id":"a","site":"demo","batch":1,"x":[1,1,1,1,1,1,1,1]}"#,
        "\n",
        r#"{"v":1,"op":"info","id":"b"}"#,
        "\n"
    );
    let mut out = Vec::new();
    let stats = serve(&mut ctx, script.as_bytes(), &mut out, &NodeOpts::default()).unwrap();
    assert_eq!((stats.requests, stats.responses, stats.errors), (2, 2, 0));
    let resp = parse_responses(&out);
    match &resp[0] {
        Response::Infer { id, y, .. } => {
            assert_eq!(id, "a");
            assert_eq!(y, &vec![4.0; 8]);
        }
        other => panic!("{other:?}"),
    }
    for line in std::str::from_utf8(&out).unwrap().trim_end().lines() {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("v").and_then(Json::as_f64), Some(2.0), "responses are stamped v2");
    }
}

#[test]
fn synthetic_session_matches_ci_golden_arithmetic() {
    // ci/golden/serve_smoke.out relies on this: diag:K places exactly K
    // nnz per row, so with all-1.0 weights an all-ones input row maps to
    // the integer K on every backend and thread count.
    for &backend in Backend::all() {
        let mut ctx = SessionCtx::synthetic("diag:4", 8, 8, 0.5, 2, backend).unwrap();
        assert_eq!(ctx.run("demo", &[1.0; 8], 1).unwrap().to_vec(), vec![4.0; 8], "{backend:?}");
        assert_eq!(ctx.run("demo", &[2.0; 8], 1).unwrap().to_vec(), vec![8.0; 8], "{backend:?}");
    }
}
