//! Property-based tests over the coordinator's invariants (hand-rolled
//! generators — the offline build has no proptest crate, so we drive the
//! same shrink-free random-case pattern from our own deterministic RNG;
//! every case prints its seed on failure for reproduction).

use padst::perm;
use padst::sparsity::compress::{compress_rows, decompress_rows};
use padst::sparsity::dst::*;
use padst::sparsity::pattern::resolve_pattern;
use padst::util::Rng;

const CASES: usize = 60;

fn arb_dims(rng: &mut Rng) -> (usize, usize) {
    let rows = [16, 32, 48, 64, 96][rng.below(5)];
    let cols = [16, 32, 48, 64, 128][rng.below(5)];
    (rows, cols)
}

/// DST updates preserve the nnz budget and the structure family, for every
/// dynamic family, across random weights/grads/fractions — driven through
/// the `SparsePattern` trait (the coordinator's own dispatch), not a
/// per-family match.
#[test]
fn prop_dst_preserves_budget_and_family() {
    let mut meta = Rng::new(0xD57);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let (rows, cols) = arb_dims(&mut rng);
        let density = [0.05, 0.1, 0.25][rng.below(3)];
        let frac = [0.1, 0.3, 0.5][rng.below(3)];
        for spec in ["diag", "block", "nm", "unstructured"] {
            let pattern = resolve_pattern(spec).unwrap();
            let mask = pattern.init_mask(rows, cols, density, &mut rng).unwrap();
            let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
            let g: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
            let new = pattern
                .prune_grow(&w, &mask, &g, frac)
                .expect("dynamic family must implement prune_grow");
            assert_eq!(
                new.nnz(),
                mask.nnz(),
                "case {case} seed {seed} {spec}: budget changed"
            );
            assert!(
                pattern.validate(&new).is_ok(),
                "case {case} seed {seed} {spec}: left family"
            );
        }
    }
}

/// Compression round-trip with a fused permutation is exact for every
/// structure with fixed row nnz.
#[test]
fn prop_compress_perm_roundtrip() {
    let mut meta = Rng::new(0xC0);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let (rows, cols) = arb_dims(&mut rng);
        let density = [0.05, 0.1, 0.25][rng.below(3)];
        let spec = ["diag", "nm", "butterfly"][rng.below(3)];
        let mask = resolve_pattern(spec)
            .unwrap()
            .init_mask(rows, cols, density, &mut rng)
            .unwrap();
        let k = (0..rows).map(|i| mask.row_nnz(i)).max().unwrap();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let pidx: Vec<i32> = rng.permutation(cols).iter().map(|&p| p as i32).collect();
        let mut inv = vec![0i32; cols];
        for (i, &p) in pidx.iter().enumerate() {
            inv[p as usize] = i as i32;
        }
        let rc = compress_rows(&w, &mask, k, Some(&pidx));
        let back = decompress_rows(&rc, Some(&inv));
        for i in 0..rows {
            for j in 0..cols {
                let want = if mask.get(i, j) { w[i * cols + j] } else { 0.0 };
                assert!(
                    (back[i * cols + j] - want).abs() < 1e-5,
                    "case {case} seed {seed} {spec}: ({i},{j})"
                );
            }
        }
    }
}

/// Hungarian decode of a soft matrix built around a planted permutation
/// recovers the plant, for any noise below the margin.
#[test]
fn prop_decode_recovers_planted() {
    let mut meta = Rng::new(0xDEC);
    for case in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let n = [4, 8, 16, 32, 64][rng.below(5)];
        let planted = rng.permutation(n);
        let mut m = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] = 0.4 * rng.f32() as f64;
            }
            m[i * n + planted[i]] = 0.5 + 0.5 * rng.f32() as f64;
        }
        let idx = perm::decode(&m, n);
        assert_eq!(idx, planted, "case {case} seed {seed} n {n}");
    }
}

/// delta(P) is in [0,1], equals 1 only for the identity, and is invariant
/// to which non-identity positions are permuted (depends only on count).
#[test]
fn prop_identity_distance_range() {
    let mut meta = Rng::new(0x1D);
    for _ in 0..CASES {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let n = 8 + rng.below(120);
        let p = rng.permutation(n);
        let d = perm::identity_distance(&p);
        assert!((0.0..=1.0).contains(&d), "seed {seed}: d={d}");
        let is_id = p.iter().enumerate().all(|(i, &x)| i == x);
        if is_id {
            assert!((d - 1.0).abs() < 1e-12);
        } else {
            assert!(d < 1.0);
        }
    }
}

/// Sinkhorn output is (near-)doubly-stochastic for arbitrary positive
/// logits; the AutoShuffle penalty is non-negative and zero on vertices.
#[test]
fn prop_sinkhorn_and_penalty() {
    let mut meta = Rng::new(0x51D4);
    for _ in 0..CASES / 2 {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        let n = 4 + rng.below(60);
        let logits: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let m = perm::soft_perm(&logits, n, 16);
        for i in 0..n {
            let rs: f64 = m[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-4, "seed {seed} row {i}: {rs}");
        }
        let pen = perm::autoshuffle_penalty(&m, n);
        assert!(pen >= -1e-9, "seed {seed}: negative penalty {pen}");
        // Vertex: penalty ~ 0.
        let planted = rng.permutation(n);
        let mut v = vec![0.0f64; n * n];
        for (i, &j) in planted.iter().enumerate() {
            v[i * n + j] = 1.0;
        }
        assert!(perm::autoshuffle_penalty(&v, n) < 1e-9);
    }
}

/// The cosine DST schedule is monotone decreasing and hits ~0 at T.
#[test]
fn prop_cosine_schedule_monotone() {
    for total in [10usize, 100, 1000] {
        let mut prev = f64::INFINITY;
        for step in 0..=total {
            let f = cosine_update_frac(step, total, 0.3);
            assert!(f <= prev + 1e-12);
            assert!((0.0..=0.3).contains(&f));
            prev = f;
        }
        assert!(cosine_update_frac(total, total, 0.3) < 1e-9);
    }
}
