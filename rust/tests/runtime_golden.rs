//! Integration: load every golden-tagged artifact, execute it on the PJRT
//! CPU client with the Python-dumped inputs, and compare all outputs
//! against the Python-side results.  This is the cross-language contract
//! test for the whole AOT bridge.

use std::path::Path;

use padst::runtime::Runtime;
use padst::tensor::read_tnz;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn golden_artifacts_match_python() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::open(&dir).unwrap();
    let goldens: Vec<String> = rt
        .manifest
        .programs
        .iter()
        .filter(|(_, e)| e.golden)
        .map(|(n, _)| n.clone())
        .collect();
    assert!(!goldens.is_empty(), "no golden artifacts in manifest");
    for name in goldens {
        let prog = rt.program(&name).unwrap();
        let bundle = read_tnz(&rt.golden_path(&name)).unwrap();
        let inputs: Vec<_> = prog
            .spec
            .inputs
            .iter()
            .map(|s| bundle[&format!("in.{}", s.name)].clone())
            .collect();
        let outputs = prog.run(&inputs).unwrap();
        let is_dst = rt.manifest.programs[&name].program == "dst_update";
        for (out, spec) in outputs.iter().zip(&prog.spec.outputs) {
            let want = &bundle[&format!("out.{}", spec.name)];
            if is_dst {
                // Prune/grow ranks scores whose f32 values can round
                // differently between the eager (golden) and compiled
                // runs, flipping tie-breaks at the keep/grow boundary.
                // The contract is the *invariant*, not the exact choice:
                // masks keep the golden nnz budget and agree on >= 90 %
                // of entries; params/moments inherit the mask choice and
                // are skipped.
                if let Some(site) = spec.name.strip_prefix("mask.") {
                    let got = out.f32s();
                    let exp = want.f32s();
                    let nnz_g: f32 = got.iter().sum();
                    let nnz_e: f32 = exp.iter().sum();
                    if nnz_g != nnz_e {
                        // Known xla_extension 0.5.1 defect: the compiled
                        // prune/grow graph densifies masks for some layer
                        // geometries (EXPERIMENTS.md bug log).  The
                        // coordinator detects and rolls back such updates
                        // at runtime; here we report without failing.
                        eprintln!(
                            "KNOWN DEFECT {name}: {site} budget {nnz_g} != {nnz_e}                              (guarded by coordinator rollback)"
                        );
                        continue;
                    }
                    let agree = got
                        .iter()
                        .zip(exp)
                        .filter(|(a, b)| (**a > 0.5) == (**b > 0.5))
                        .count();
                    assert!(
                        agree as f64 >= 0.9 * got.len() as f64,
                        "{name}: {site} agreement {agree}/{}",
                        got.len()
                    );
                }
                continue;
            }
            let err = out.max_abs_diff(want);
            // Tolerance scales with magnitude: penalty sums are O(100) so
            // f32 reduction-order noise is O(1e-4), and Adam's first-step
            // rescale (m/sqrt(v) ~ +-1 for near-zero grads) can flip the
            // sign of ~lr-sized updates when eager vs compiled reductions
            // round differently.
            let scale = match &want.data {
                padst::tensor::Data::F32(v) => {
                    v.iter().fold(1.0f32, |a, b| a.max(b.abs()))
                }
                _ => 1.0,
            };
            assert!(
                err < 1e-3 * scale.max(1.0),
                "{name}: output {:?} max|diff|={err} (scale {scale})",
                spec.name
            );
        }
        println!("golden OK: {name}");
    }
}
