#!/usr/bin/env python3
"""Binary-wire smoke for `padst serve` (protocol v2), stdlib only.

Drives one stdin/stdout session of the synthetic diag:4 8x8 node with a
mixed text/binary script and prints a canonical transcript for `diff`
against ci/golden/serve_binary_smoke.out:

  1. text  hello wire=binary  -> text ack (acks are always NDJSON)
  2. binary infer x=[1]*8     -> binary response y=[4]*8 (mirrors format)
  3. text  infer x=[2]*8      -> binary response y=[8]*8 (hello preference)
  4. text  hello wire=ndjson  -> text ack, preference cleared
  5. text  infer x=[1]*8      -> text response y=[4]*8

All-ones weights on diag:4 make every activation an exact small integer,
so the transcript is stable across platforms, backends and threads.

Usage: serve_binary_smoke.py /path/to/padst
"""

import io
import struct
import subprocess
import sys

MAGIC = b"\xbfPA2"
KIND_REQUEST, KIND_RESPONSE = 1, 2


def encode_infer(rid, site, batch, x, more=False):
    body = struct.pack("<BB", KIND_REQUEST, 1 if more else 0)
    body += struct.pack("<H", len(rid)) + rid.encode()
    body += struct.pack("<H", len(site)) + site.encode()
    body += struct.pack("<II", batch, len(x))
    body += struct.pack("<%df" % len(x), *x)
    return MAGIC + struct.pack("<I", len(body)) + body


def read_frames(stream):
    """Yield ('TEXT', line) / ('BIN', decoded) off a mixed response stream."""
    while True:
        b = stream.read(1)
        if not b:
            return
        if b in (b"\n", b"\r"):
            continue
        if b == MAGIC[:1]:
            rest = stream.read(3)
            assert b + rest == MAGIC, "bad magic %r" % (b + rest)
            (blen,) = struct.unpack("<I", stream.read(4))
            body = stream.read(blen)
            assert len(body) == blen, "truncated body"
            yield ("BIN", decode_body(body))
        else:
            line = b + stream.readline()
            yield ("TEXT", line.decode().rstrip("\n"))


def decode_body(body):
    kind, _flags = struct.unpack_from("<BB", body, 0)
    assert kind == KIND_RESPONSE, "unexpected kind %d" % kind
    off = 2
    (idlen,) = struct.unpack_from("<H", body, off)
    off += 2
    rid = body[off : off + idlen].decode()
    off += idlen
    batch, nvals = struct.unpack_from("<II", body, off)
    off += 8
    y = struct.unpack_from("<%df" % nvals, body, off)
    assert off + 4 * nvals == len(body), "trailing bytes"
    return rid, batch, y


def main():
    padst = sys.argv[1] if len(sys.argv) > 1 else "./target/release/padst"
    script = io.BytesIO()
    script.write(b'{"v":2,"op":"hello","id":"h","wire":"binary"}\n')
    script.write(encode_infer("p", "demo", 1, [1.0] * 8))
    script.write(b'{"v":2,"op":"infer","id":"q","site":"demo","batch":1,"x":[2,2,2,2,2,2,2,2]}\n')
    script.write(b'{"v":2,"op":"hello","id":"h2","wire":"ndjson"}\n')
    script.write(b'{"v":2,"op":"infer","id":"r","site":"demo","batch":1,"x":[1,1,1,1,1,1,1,1]}\n')
    out = subprocess.run(
        [padst, "serve", "--synthetic", "diag:4", "--rows", "8", "--cols", "8", "--threads", "2"],
        input=script.getvalue(),
        stdout=subprocess.PIPE,
        timeout=120,
        check=True,
    ).stdout
    for kind, frame in read_frames(io.BufferedReader(io.BytesIO(out))):
        if kind == "TEXT":
            print("TEXT %s" % frame)
        else:
            rid, batch, y = frame
            vals = ",".join("%g" % v for v in y)
            print("BIN id=%s batch=%d y=[%s]" % (rid, batch, vals))


if __name__ == "__main__":
    main()
