#!/usr/bin/env python3
"""Two-connection Unix-socket smoke for `padst serve`, stdlib only.

Starts the synthetic diag:4 8x8 node on a Unix socket with
`--max-conns 2`, opens two concurrent connections, interleaves text
infer frames across them (plus one binary frame on connection B), and
prints connection A's transcript then connection B's for `diff` against
ci/golden/serve_socket_smoke.out.

Each connection's own responses arrive in its own request order no
matter how the two workers interleave on the kernel layer, so the
per-connection transcripts — and the A-then-B print order — are
deterministic.  All-ones weights on diag:4 keep every activation an
exact small integer (x=[k]*8 -> y=[4k]*8), stable across platforms,
backends and thread counts.

Usage: serve_socket_smoke.py /path/to/padst
"""

import json
import os
import socket
import struct
import subprocess
import sys
import tempfile
import time

MAGIC = b"\xbfPA2"
KIND_REQUEST, KIND_RESPONSE = 1, 2


def infer_line(rid, x):
    req = {"v": 2, "op": "infer", "id": rid, "site": "demo", "batch": 1, "x": x}
    return (json.dumps(req) + "\n").encode()


def encode_infer(rid, site, batch, x):
    body = struct.pack("<BB", KIND_REQUEST, 0)
    body += struct.pack("<H", len(rid)) + rid.encode()
    body += struct.pack("<H", len(site)) + site.encode()
    body += struct.pack("<II", batch, len(x))
    body += struct.pack("<%df" % len(x), *x)
    return MAGIC + struct.pack("<I", len(body)) + body


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        assert chunk, "connection closed mid-frame"
        buf += chunk
    return buf


def recv_text(sock):
    line = b""
    while not line.endswith(b"\n"):
        line += recv_exact(sock, 1)
    return line.decode().rstrip("\n")


def recv_binary(sock):
    assert recv_exact(sock, 4) == MAGIC, "bad magic"
    (blen,) = struct.unpack("<I", recv_exact(sock, 4))
    body = recv_exact(sock, blen)
    kind, _flags = struct.unpack_from("<BB", body, 0)
    assert kind == KIND_RESPONSE, "unexpected kind %d" % kind
    off = 2
    (idlen,) = struct.unpack_from("<H", body, off)
    off += 2
    rid = body[off : off + idlen].decode()
    off += idlen
    batch, nvals = struct.unpack_from("<II", body, off)
    off += 8
    y = struct.unpack_from("<%df" % nvals, body, off)
    vals = ",".join("%g" % v for v in y)
    return "BIN id=%s batch=%d y=[%s]" % (rid, batch, vals)


def connect(path, deadline=60.0):
    t0 = time.time()
    while True:
        try:
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.connect(path)
            return s
        except OSError:
            s.close()
            if time.time() - t0 > deadline:
                raise
            time.sleep(0.05)


def main():
    padst = sys.argv[1] if len(sys.argv) > 1 else "./target/release/padst"
    sock_path = os.path.join(tempfile.mkdtemp(prefix="padst_smoke_"), "serve.sock")
    node = subprocess.Popen(
        [padst, "serve", "--synthetic", "diag:4", "--rows", "8", "--cols", "8",
         "--threads", "2", "--socket", sock_path, "--max-conns", "2"],
    )
    try:
        a = connect(sock_path)
        b = connect(sock_path)
        transcript_a, transcript_b = [], []
        # Interleave across the two live connections; each answer is read
        # before the next frame goes out, so both workers are provably
        # serving at once (not queued behind each other).
        a.sendall(infer_line("a1", [1] * 8))
        transcript_a.append(recv_text(a))
        b.sendall(infer_line("b1", [2] * 8))
        transcript_b.append(recv_text(b))
        a.sendall(infer_line("a2", [3] * 8))
        transcript_a.append(recv_text(a))
        b.sendall(infer_line("b2", [4] * 8))
        transcript_b.append(recv_text(b))
        # Binary frames work over the socket too, mirrored per frame.
        b.sendall(encode_infer("b3", "demo", 1, [1.0] * 8))
        transcript_b.append(recv_binary(b))
        a.close()
        b.close()
        for line in transcript_a:
            print("A %s" % line)
        for line in transcript_b:
            print("B %s" % line)
    finally:
        node.terminate()
        node.wait(timeout=30)


if __name__ == "__main__":
    main()
